"""The MoE shard_map paths (train manual-FSDP gathers; serve TP psum) must
produce the same results as the single-device local path — the correctness
guarantee behind EXPERIMENTS.md §Perf it.3."""

import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.dist.sharding import make_ctx
from repro.launch.mesh import make_mesh_from_devices
from repro.models.moe import moe_ffn, moe_ffn_local

rng = np.random.default_rng(0)
B, S, D, E, F, K = 4, 16, 32, 4, 64, 2
x = jnp.asarray(rng.normal(0, 1, (B, S, D)).astype(np.float32)).astype(jnp.bfloat16)
params = {
    "router": jnp.asarray(rng.normal(0, 0.1, (D, E)).astype(np.float32)),
    "w_gate": jnp.asarray(rng.normal(0, 0.1, (E, D, F)).astype(np.float32)),
    "w_up": jnp.asarray(rng.normal(0, 0.1, (E, D, F)).astype(np.float32)),
    "w_down": jnp.asarray(rng.normal(0, 0.1, (E, F, D)).astype(np.float32)),
}
want = moe_ffn(x, params, k=K, ctx=None)

mesh = make_mesh_from_devices((4, 2), ("data", "model"))
for mode in ("train", "serve"):
    ctx = make_ctx(mesh, mode=mode)
    with mesh:
        got = jax.jit(lambda x, p: moe_ffn(x, p, k=K, ctx=ctx))(x, params)
    # token partitioning changes per-shard capacity cutoffs; with ample
    # capacity (dropless region) results must agree to bf16 tolerance
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=5e-2, atol=5e-2,
    )
    print(f"MOE_{mode.upper()}_OK")

# gradient flows through the manual-FSDP gathers
ctx = make_ctx(mesh, mode="train")
def loss(p):
    return jnp.sum(jnp.square(moe_ffn(x, p, k=K, ctx=ctx).astype(jnp.float32)))
with mesh:
    g = jax.jit(jax.grad(loss))(params)
gn = float(jnp.sqrt(sum(jnp.sum(jnp.square(v)) for v in jax.tree.leaves(g))))
assert np.isfinite(gn) and gn > 0
print("MOE_GRAD_OK", gn)
"""


def test_moe_shard_map_matches_local():
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
        cwd="/root/repo",
    )
    assert "MOE_TRAIN_OK" in out.stdout, out.stderr[-3000:]
    assert "MOE_SERVE_OK" in out.stdout, out.stderr[-3000:]
    assert "MOE_GRAD_OK" in out.stdout, out.stderr[-3000:]

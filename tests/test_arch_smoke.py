"""Per-architecture smoke tests: a REDUCED config of each assigned arch runs
one forward/train step and one decode step on CPU, asserting output shapes and
finiteness. Full configs are exercised only by the dry-run (launch/dryrun.py).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced_config
from repro.models import decode_step, forward_train, init_cache, init_params, prefill

B, S = 2, 32


def make_batch(cfg, rng):
    if cfg.family == "audio":
        return {
            "frame_embeds": jnp.asarray(
                rng.normal(0, 1, (B, S, cfg.d_model)).astype(np.float32)
            ),
            "labels": jnp.asarray(
                rng.integers(0, cfg.vocab_size, (B, S, cfg.num_codebooks))
            ).astype(jnp.int32),
        }
    if cfg.family == "vlm":
        s_text = S - cfg.num_patches
        return {
            "patch_embeds": jnp.asarray(
                rng.normal(0, 1, (B, cfg.num_patches, cfg.d_model)).astype(np.float32)
            ),
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, s_text))).astype(jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, s_text))).astype(jnp.int32),
        }
    return {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S))).astype(jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S))).astype(jnp.int32),
    }


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch_setup(request):
    cfg = reduced_config(get_config(request.param))
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(1)
    return request.param, cfg, params, rng


class TestSmoke:
    def test_train_step_loss_finite(self, arch_setup):
        name, cfg, params, rng = arch_setup
        batch = make_batch(cfg, rng)
        loss, grads = jax.value_and_grad(
            lambda p: forward_train(cfg, p, batch)
        )(params)
        assert loss.shape == ()
        assert bool(jnp.isfinite(loss)), f"{name}: loss={loss}"
        # plausible initial CE: ~log(vocab)
        assert 0.0 < float(loss) < 2.0 * np.log(cfg.padded_vocab) + 5.0
        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
        )
        assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0.0

    def test_decode_step_shapes(self, arch_setup):
        name, cfg, params, rng = arch_setup
        cache = init_cache(cfg, B, max_len=S)
        if cfg.family == "audio":
            batch = {
                "frame_embeds": jnp.asarray(
                    rng.normal(0, 1, (B, 1, cfg.d_model)).astype(np.float32)
                )
            }
            want_v = cfg.num_codebooks * cfg.padded_vocab
        else:
            batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1))).astype(jnp.int32)}
            want_v = cfg.padded_vocab
        logits, cache2 = decode_step(cfg, params, batch, cache, jnp.int32(3))
        assert logits.shape == (B, want_v)
        assert bool(jnp.all(jnp.isfinite(logits))), name
        # cache structure preserved
        assert jax.tree.structure(cache) == jax.tree.structure(cache2)
        for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(cache2)):
            assert a.shape == b.shape

    def test_prefill_then_decode_consistency(self, arch_setup):
        """prefill(t_0..t_{n-1}) followed by decode(t_n) must equal the
        decode-only rollout of the same tokens (state equivalence)."""
        name, cfg, params, rng = arch_setup
        if cfg.family in ("vlm", "audio"):
            pytest.skip("covered by token archs; stub frontends differ")
        n = 8
        toks = rng.integers(0, cfg.vocab_size, (B, n + 1)).astype(np.int32)
        logits_p, cache_p, ln = prefill(
            cfg, params, {"tokens": jnp.asarray(toks[:, :n])}, max_len=S
        )
        got, _ = decode_step(
            cfg, params, {"tokens": jnp.asarray(toks[:, n : n + 1])}, cache_p, jnp.int32(n)
        )
        # decode-only rollout
        cache = init_cache(cfg, B, max_len=S)
        for i in range(n + 1):
            want, cache = decode_step(
                cfg, params, {"tokens": jnp.asarray(toks[:, i : i + 1])}, cache, jnp.int32(i)
            )
        # recurrent families carry bf16 state through S×L sequential updates;
        # chunked-parallel vs sequential orders differ in rounding
        tol = 0.2 if cfg.family in ("hybrid", "ssm") else 3e-2
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


def test_full_configs_match_assignment():
    """Exact published numbers for every assigned architecture."""
    import repro.configs.base as base

    expect = {
        "mixtral_8x7b": (32, 4096, 32, 8, 14336, 32000),
        "granite_moe_1b_a400m": (24, 1024, 16, 8, 512, 49155),
        "gemma3_1b": (26, 1152, 4, 1, 6912, 262144),
        "phi3_medium_14b": (40, 5120, 40, 10, 17920, 100352),
        "granite_3_8b": (40, 4096, 32, 8, 12800, 49155),
        "yi_6b": (32, 4096, 32, 4, 11008, 64000),
        "zamba2_2p7b": (54, 2560, 32, 32, 10240, 32000),
        "paligemma_3b": (18, 2048, 8, 1, 16384, 257216),
        "rwkv6_1p6b": (24, 2048, 32, 32, 7168, 65536),
        "musicgen_medium": (48, 1536, 24, 24, 6144, 2048),
    }
    for arch, (L, d, h, kv, ff, v) in expect.items():
        cfg = get_config(arch)
        assert (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (L, d, h, kv, ff, v), arch
    # family-specific invariants
    assert get_config("mixtral_8x7b").num_experts == 8
    assert get_config("mixtral_8x7b").experts_per_token == 2
    assert get_config("mixtral_8x7b").window == 4096
    assert get_config("granite_moe_1b_a400m").num_experts == 32
    assert get_config("granite_moe_1b_a400m").experts_per_token == 8
    assert get_config("gemma3_1b").global_every == 6
    assert get_config("zamba2_2p7b").ssm_state == 64
    assert get_config("zamba2_2p7b").attn_every == 6
    assert get_config("rwkv6_1p6b").rwkv
    assert get_config("musicgen_medium").num_codebooks == 4
    assert get_config("paligemma_3b").num_patches == 256
    # padded vocab shards over 16 for every arch
    for arch in base.ARCH_IDS:
        assert get_config(arch).padded_vocab % 256 == 0


def test_param_counts_plausible():
    """param_count() must land near the published sizes (within 25%)."""
    approx = {
        "mixtral_8x7b": 46.7e9,
        "phi3_medium_14b": 14e9,
        "granite_3_8b": 8e9,
        "yi_6b": 6e9,
        "zamba2_2p7b": 2.7e9,
        "paligemma_3b": 2.6e9,   # decoder-only part of the 3B (SigLIP is a stub)
        "rwkv6_1p6b": 1.6e9,
        "musicgen_medium": 1.5e9,
        "gemma3_1b": 1.0e9,
        "granite_moe_1b_a400m": 1.3e9,
    }
    for arch, want in approx.items():
        got = get_config(arch).param_count()
        assert 0.6 * want < got < 1.6 * want, f"{arch}: {got/1e9:.2f}B vs {want/1e9:.2f}B"


def test_moe_active_params():
    cfg = get_config("mixtral_8x7b")
    active = cfg.active_param_count()
    assert 10e9 < active < 16e9  # ~12.9B active for top-2

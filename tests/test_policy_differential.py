"""Differential/property harness for the whole policy matrix (ISSUE 2).

The contract under test is the engine's strongest claim: for ANY workflow,
ANY parameter sets and ANY input, all five policies (`none`/`stage`/`rtma`/
`rmsr`/`hybrid`), both executors (`execute_plan` and `execute_study`) and
every worker count produce **bit-identical** per-run outputs equal to the
straight-line no-reuse oracle — while the reuse policies never execute more
tasks than the `stage` baseline, and `execute_study` starts exactly ONE
Manager session per study (vs one per input for sequential execution).

Random cases come from the seeded generator in ``study_gen`` so the suite
is deterministic without hypothesis; when hypothesis is installed an extra
shrinkable property layer drives the same checks (derandomized under CI via
conftest's "ci" profile).
"""

import random

import pytest

from repro.engine import ClusterSpec, execute_plan, execute_study, plan_study
from repro.engine.types import POLICIES
from repro.runtime.manager import Manager

from study_gen import naive_outputs, random_param_sets, random_workflow

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _random_case(seed: int):
    rng = random.Random(seed)
    wf, names, cards = random_workflow(rng)
    sets = random_param_sets(rng, names, cards, rng.randint(1, 24))
    inputs = [rng.randrange(1 << 40) for _ in range(rng.randint(1, 4))]
    plan_kwargs = {
        "max_bucket_size": rng.choice([1, 2, 3, None]),
        "active_paths": rng.choice([1, 2, None]),
    }
    return wf, sets, inputs, plan_kwargs


def _check_case(wf, sets, inputs, plan_kwargs, workers=(1, 3)):
    oracles = [naive_outputs(wf, sets, x) for x in inputs]
    stage_plan = plan_study(wf, sets, policy="stage")
    for pol in POLICIES:
        plan = plan_study(wf, sets, policy=pol, **plan_kwargs)
        if pol in ("rtma", "rmsr", "hybrid"):
            # reuse never does MORE work than the coarse-dedup baseline
            assert plan.tasks_executed <= stage_plan.tasks_executed, pol
        assert plan.tasks_executed <= plan.tasks_total
        for w in workers:
            cluster = ClusterSpec(n_workers=w)
            for i, x in enumerate(inputs):
                res = execute_plan(plan, x, cluster=cluster)
                assert res.outputs == oracles[i], (pol, w, i)

            before = Manager.sessions_started
            stream = execute_study(plan, inputs, cluster=cluster)
            # one persistent session per study, not one per stage×input
            assert Manager.sessions_started - before == 1, (pol, w)
            assert stream.manager_sessions == 1
            for i in range(len(inputs)):
                assert stream.outputs[i] == oracles[i], (pol, w, i)
                assert stream.per_input[i].outputs == oracles[i]
            # accounting: executed + cache hits covers every planned task,
            # for every input, with nothing double-counted
            assert (
                stream.tasks_executed + stream.cache_hits
                == plan.tasks_executed * len(inputs)
            ), (pol, w)


@pytest.mark.parametrize("seed", range(10))
def test_differential_policy_matrix(seed):
    wf, sets, inputs, plan_kwargs = _random_case(9000 + seed)
    _check_case(wf, sets, inputs, plan_kwargs)


def test_reuse_policies_never_exceed_stage_baseline_work():
    """Task-count ordering across the matrix, on a batch of random cases:
    none == total ≥ stage ≥ rtma == hybrid ≥ rmsr."""
    for seed in range(25):
        rng = random.Random(5000 + seed)
        wf, names, cards = random_workflow(rng)
        sets = random_param_sets(rng, names, cards, rng.randint(2, 32))
        plans = {
            pol: plan_study(wf, sets, policy=pol, max_bucket_size=4, active_paths=2)
            for pol in POLICIES
        }
        assert plans["none"].tasks_executed == plans["none"].tasks_total
        assert plans["stage"].tasks_executed <= plans["none"].tasks_executed
        assert plans["rtma"].tasks_executed <= plans["stage"].tasks_executed
        assert plans["hybrid"].tasks_executed == plans["rtma"].tasks_executed
        assert plans["rmsr"].tasks_executed <= plans["rtma"].tasks_executed


def test_study_of_one_input_equals_execute_plan_accounting():
    wf, sets, inputs, plan_kwargs = _random_case(77)
    plan = plan_study(wf, sets, policy="hybrid", **plan_kwargs)
    res = execute_plan(plan, inputs[0])
    stream = execute_study(plan, [inputs[0]])
    assert stream.outputs[0] == res.outputs
    assert stream.tasks_executed == res.tasks_executed
    assert stream.cache_hits == res.cache_hits
    assert stream.per_input[0].per_stage_executed == res.per_stage_executed


def test_cross_input_cache_isolation():
    """Two different inputs through one cached (hybrid) study: the
    input-scoped cache segment must keep their merged prefixes apart even
    when every parameter agrees — a collision would surface as input B
    receiving input A's outputs."""
    rng = random.Random(31337)
    wf, names, cards = random_workflow(rng, max_stages=2)
    sets = random_param_sets(rng, names, cards, 12)
    inputs = [1, 2]  # adjacent ints: identical params, different input
    stream = execute_study(plan_study(wf, sets, policy="hybrid"), inputs)
    for i, x in enumerate(inputs):
        assert stream.outputs[i] == naive_outputs(wf, sets, x), i
    assert stream.outputs[0] != stream.outputs[1]


if HAVE_HYPOTHESIS:

    class TestHypothesisDifferential:
        @given(
            seed=st.integers(min_value=0, max_value=2**20),
            n_runs=st.integers(min_value=1, max_value=16),
            n_inputs=st.integers(min_value=1, max_value=3),
            workers=st.sampled_from([1, 2, 4]),
        )
        @settings(max_examples=15, deadline=None)
        def test_policy_matrix_bit_identical(self, seed, n_runs, n_inputs, workers):
            rng = random.Random(seed)
            wf, names, cards = random_workflow(rng)
            sets = random_param_sets(rng, names, cards, n_runs)
            inputs = [rng.randrange(1 << 40) for _ in range(n_inputs)]
            plan_kwargs = {
                "max_bucket_size": rng.choice([1, 2, None]),
                "active_paths": rng.choice([1, 2, None]),
            }
            _check_case(wf, sets, inputs, plan_kwargs, workers=(workers,))

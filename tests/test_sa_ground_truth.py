"""core.sa correctness on analytic ground truth (paper §II-A methods).

VBD (Saltelli/Jansen) against the Ishigami function and a linear additive
model — both with closed-form Sobol indices — inside tolerance bands that
account for Monte-Carlo error and grid quantisation; MOAT μ* ranking on a
monotone function with known coefficient ordering; and fixed-seed
determinism of the samplers (the adaptive driver's resume/oracle machinery
relies on it).
"""

import numpy as np
import pytest

from repro.core.params import ParamSpace, morris_trajectories
from repro.core.sa import moat_indices, saltelli_sample, vbd_indices


def uniform_grid_space(names, lo, hi, levels):
    """Equal-probability grids whose cell midpoints tile [lo, hi]."""
    vals = [lo + (hi - lo) * (i + 0.5) / levels for i in range(levels)]
    return ParamSpace.from_dict({n: vals for n in names})


def evaluate(space, param_sets, fn):
    names = space.names
    return [fn(**{n: dict(ps)[n] for n in names}) for ps in param_sets]


class TestVbdGroundTruth:
    def test_ishigami(self):
        """Ishigami (a=7, b=0.1): the canonical nonlinear/ non-monotone SA
        benchmark with closed-form indices."""
        a, b = 7.0, 0.1
        space = uniform_grid_space(["x1", "x2", "x3"], -np.pi, np.pi, 128)
        sets, n_base = saltelli_sample(space, 4096, seed=7)
        y = evaluate(
            space, sets,
            lambda x1, x2, x3: np.sin(x1) + a * np.sin(x2) ** 2 + b * x3**4 * np.sin(x1),
        )
        res = vbd_indices(space, y, n_base)

        V = a**2 / 8 + b * np.pi**4 / 5 + b**2 * np.pi**8 / 18 + 0.5
        S1 = (b * np.pi**4 / 5 + b**2 * np.pi**8 / 50 + 0.5) / V
        S2 = (a**2 / 8) / V
        ST1 = S1 + (b**2 * np.pi**8 * (1 / 18 - 1 / 50)) / V
        ST3 = ST1 - S1
        want_first = {"x1": S1, "x2": S2, "x3": 0.0}
        want_total = {"x1": ST1, "x2": S2, "x3": ST3}
        for name in space.names:
            assert res.first_order[name] == pytest.approx(want_first[name], abs=0.06)
            assert res.total[name] == pytest.approx(want_total[name], abs=0.06)

    def test_linear_model(self):
        """Additive model y = Σ c_i x_i: S_i = S_Ti = c_i² / Σ c_j²."""
        c = {"a": 4.0, "b": 2.0, "cc": 1.0}
        space = uniform_grid_space(list(c), 0.0, 1.0, 64)
        sets, n_base = saltelli_sample(space, 8192, seed=0)
        y = evaluate(space, sets, lambda a, b, cc: c["a"] * a + c["b"] * b + c["cc"] * cc)
        res = vbd_indices(space, y, n_base)
        denom = sum(v**2 for v in c.values())
        for name, coef in c.items():
            want = coef**2 / denom
            assert res.first_order[name] == pytest.approx(want, abs=0.05)
            assert res.total[name] == pytest.approx(want, abs=0.05)

    def test_bootstrap_ci_brackets_estimate(self):
        space = uniform_grid_space(["a", "b"], 0.0, 1.0, 32)
        sets, n_base = saltelli_sample(space, 1024, seed=1)
        y = evaluate(space, sets, lambda a, b: 3.0 * a + b)
        plain = vbd_indices(space, y, n_base)
        assert plain.total_ci is None and plain.first_order_ci is None
        boot = vbd_indices(space, y, n_base, n_boot=200, seed=5)
        for name in space.names:
            for point, ci in ((boot.total, boot.total_ci), (boot.first_order, boot.first_order_ci)):
                lo, hi = ci[name]
                assert lo <= point[name] <= hi
            lo, hi = boot.total_ci[name]
            assert hi - lo < 0.2  # noiseless additive model: tight S_Ti


class TestMoatGroundTruth:
    def test_monotone_ranking(self):
        """On y = 10a + 3b + 0.1c, μ* must rank a > b > c (each elementary
        effect is exactly coef × the step taken)."""
        space = uniform_grid_space(["a", "b", "cc"], 0.0, 1.0, 16)
        sets, moves = morris_trajectories(space, 8, seed=2)
        y = evaluate(space, sets, lambda a, b, cc: 10.0 * a + 3.0 * b + 0.1 * cc)
        res = moat_indices(space, y, moves)
        assert res.ranking() == ["a", "b", "cc"]
        assert res.mu_star["a"] > res.mu_star["b"] > res.mu_star["cc"] > 0

    def test_inert_parameter_zero_mu_star(self):
        space = uniform_grid_space(["live", "dead"], 0.0, 1.0, 8)
        sets, moves = morris_trajectories(space, 6, seed=0)
        y = evaluate(space, sets, lambda live, dead: live**2)
        res = moat_indices(space, y, moves)
        assert res.mu_star["dead"] == 0.0
        assert res.mu_star["live"] > 0.0

    def test_bootstrap_ci_brackets_estimate(self):
        space = uniform_grid_space(["a", "b"], 0.0, 1.0, 8)
        sets, moves = morris_trajectories(space, 8, seed=4)
        y = evaluate(space, sets, lambda a, b: 2.0 * a + b)
        res = moat_indices(space, y, moves, n_boot=200, seed=1)
        for name in space.names:
            lo, hi = res.mu_star_ci[name]
            assert lo <= res.mu_star[name] <= hi


class TestSamplerDeterminism:
    def test_saltelli_fixed_seed(self):
        space = uniform_grid_space(["a", "b", "cc"], 0.0, 1.0, 16)
        s1, n1 = saltelli_sample(space, 64, seed=9)
        s2, n2 = saltelli_sample(space, 64, seed=9)
        assert s1 == s2 and n1 == n2
        s3, _ = saltelli_sample(space, 64, seed=10)
        assert s3 != s1

    def test_morris_fixed_seed(self):
        space = uniform_grid_space(["a", "b", "cc"], 0.0, 1.0, 16)
        r1 = morris_trajectories(space, 4, seed=9)
        r2 = morris_trajectories(space, 4, seed=9)
        assert r1 == r2
        r3 = morris_trajectories(space, 4, seed=11)
        assert r3 != r1

    def test_saltelli_block_structure(self):
        """Run order is [A, B, A_B^(0), ..., A_B^(d-1)]: block i agrees with
        A except (possibly) at parameter i, where it carries B's value."""
        space = uniform_grid_space(["a", "b"], 0.0, 1.0, 32)
        sets, n = saltelli_sample(space, 16, seed=0)
        d = space.dim
        assert len(sets) == n * (d + 2)
        A, B = sets[:n], sets[n : 2 * n]
        for i, name in enumerate(space.names):
            block = sets[(2 + i) * n : (3 + i) * n]
            for j in range(n):
                da, db, dab = dict(A[j]), dict(B[j]), dict(block[j])
                assert dab[name] == db[name]
                for other in space.names:
                    if other != name:
                        assert dab[other] == da[other]

"""ssm_scan Pallas kernel vs the per-token lax.scan oracle."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need hypothesis; skip cleanly without it
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.kernels.ref import ssm_scan_ref
from repro.kernels.ssm_scan import ssm_scan_pallas


def case(b, s, h, n, p, per_channel, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, (b, s, h, p)).astype(np.float32)
    a_shape = (b, s, h, n) if per_channel else (b, s, h)
    a = np.exp(-np.exp(rng.normal(-1.0, 0.7, a_shape))).astype(np.float32)  # (0,1)
    bb = rng.normal(0, 0.5, (b, s, h, n)).astype(np.float32)
    c = rng.normal(0, 0.5, (b, s, h, n)).astype(np.float32)
    return map(jnp.asarray, (x, a, bb, c))


@pytest.mark.parametrize("per_channel", [False, True], ids=["mamba2", "rwkv6"])
@pytest.mark.parametrize(
    "b,s,h,n,p,chunk",
    [
        (1, 16, 1, 4, 4, 8),
        (2, 32, 2, 8, 16, 8),
        (1, 33, 1, 8, 8, 16),   # non-multiple seq length (padding path)
        (1, 64, 3, 16, 32, 64),
    ],
)
def test_kernel_matches_ref(per_channel, b, s, h, n, p, chunk):
    x, a, bb, c = case(b, s, h, n, p, per_channel, seed=s * 7 + n)
    y_ref, h_ref = ssm_scan_ref(x, a, bb, c)
    y, hf = ssm_scan_pallas(x, a, bb, c, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(h_ref), rtol=2e-4, atol=2e-4)


def test_strong_decay_stability():
    """Near-zero decays underflow cumulative products; the log-space chunked
    form must stay finite and match the oracle."""
    b, s, h, n, p = 1, 48, 1, 8, 8
    rng = np.random.default_rng(0)
    x = rng.normal(0, 1, (b, s, h, p)).astype(np.float32)
    a = np.full((b, s, h, n), 1e-6, np.float32)  # brutal decay
    bb = rng.normal(0, 1, (b, s, h, n)).astype(np.float32)
    c = rng.normal(0, 1, (b, s, h, n)).astype(np.float32)
    y_ref, _ = ssm_scan_ref(*map(jnp.asarray, (x, a, bb, c)))
    y, _ = ssm_scan_pallas(*map(jnp.asarray, (x, a, bb, c)), chunk=16, interpret=True)
    assert np.isfinite(np.asarray(y)).all()
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(
    s=st.integers(min_value=4, max_value=70),
    chunk=st.sampled_from([4, 8, 16, 32]),
    per_channel=st.booleans(),
    seed=st.integers(min_value=0, max_value=1000),
)
def test_property_chunk_invariance(s, chunk, per_channel, seed):
    x, a, bb, c = case(1, s, 2, 4, 8, per_channel, seed)
    y_ref, _ = ssm_scan_ref(x, a, bb, c)
    y, _ = ssm_scan_pallas(x, a, bb, c, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=3e-4, atol=3e-4)

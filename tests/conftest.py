"""Shared test configuration.

Registers a deterministic hypothesis profile ("ci": derandomized, no
deadline) and loads it when running under CI, so the property suites are
reproducible run-to-run and tier-1 stays deterministic. Local runs keep
hypothesis' default randomized exploration (profile "dev").

Also arms a faulthandler watchdog for the whole session: the runtime
suites exercise real threads, sockets, and spawned processes, and the
historical failure mode of a concurrency bug here is a silent hang, not
a traceback. The watchdog periodically dumps every thread's stack to
stderr after ``REPRO_TEST_WATCHDOG`` seconds (default 600; ``0``
disables), so a wedged run shows WHERE it is wedged instead of timing
out mutely in CI. It never kills the run (``exit=False``) — pytest's own
timeout machinery stays in charge of failing it.
"""

import faulthandler
import os

import pytest


@pytest.fixture(autouse=True, scope="session")
def _hang_watchdog():
    timeout = float(os.environ.get("REPRO_TEST_WATCHDOG", "600"))
    armed = timeout > 0 and hasattr(faulthandler, "dump_traceback_later")
    if armed:
        faulthandler.dump_traceback_later(timeout, repeat=True, exit=False)
    try:
        yield
    finally:
        if armed:
            faulthandler.cancel_dump_traceback_later()

try:
    from hypothesis import HealthCheck, settings
except ImportError:  # hypothesis is optional; property tests importorskip it
    pass
else:
    settings.register_profile(
        "ci",
        deadline=None,
        max_examples=25,
        derandomize=True,
        suppress_health_check=[HealthCheck.too_slow],
    )
    settings.register_profile("dev", deadline=None)
    settings.load_profile("ci" if os.environ.get("CI") else "dev")

"""Shared test configuration.

Registers a deterministic hypothesis profile ("ci": derandomized, no
deadline) and loads it when running under CI, so the property suites are
reproducible run-to-run and tier-1 stays deterministic. Local runs keep
hypothesis' default randomized exploration (profile "dev").
"""

import os

try:
    from hypothesis import HealthCheck, settings
except ImportError:  # hypothesis is optional; property tests importorskip it
    pass
else:
    settings.register_profile(
        "ci",
        deadline=None,
        max_examples=25,
        derandomize=True,
        suppress_health_check=[HealthCheck.too_slow],
    )
    settings.register_profile("dev", deadline=None)
    settings.load_profile("ci" if os.environ.get("CI") else "dev")

"""Adaptive multi-round study driver (repro.study) tests.

Acceptance (ISSUE 3): an adaptive MOAT → prune → VBD study executes
strictly fewer tasks than the same rounds run as independent one-shot
studies — asserted via cache counters — while producing bit-identical
objective vectors and indices to the one-shot oracle; and a study resumed
from a persisted StudyState + disk store recomputes zero already-cached
tasks. The TABLE1_SPACE version runs the real pathology workflow.
"""

import os

import numpy as np
import pytest

from repro.core import ParamSpace, StageSpec, TaskSpec, Workflow
from repro.core.params import ParamSet
from repro.core.sa import moat_indices, vbd_indices
from repro.engine import ClusterSpec, execute_study, plan_study
from repro.runtime.manager import Manager
from repro.study import (
    MoatSampler,
    RefinementSampler,
    SaltelliSampler,
    ScreenThenRefinePolicy,
    StudyDriver,
    StudyState,
    active_space,
)

WEIGHTS = (8.0, 0.0, 2.0, 0.01)  # per-task param weight: p1 inert, p3 ~inert


def make_workflow(calls=None):
    """(param-free norm, 4-task seg); task i adds WEIGHTS[i] * p_i."""

    def make_fn(i):
        def fn(x, **kw):
            if calls is not None:
                calls.append(i)
            return x + WEIGHTS[i] * sum(kw.values())

        return fn

    norm = StageSpec(
        name="norm",
        tasks=(TaskSpec("normalize", (), fn=lambda x: x * 2.0, cost=1.0, output_bytes=8),),
    )
    seg = StageSpec(
        name="seg",
        tasks=tuple(
            TaskSpec(
                name=f"seg_t{i}",
                param_names=(f"p{i}",),
                fn=make_fn(i),
                cost=1.0,
                output_bytes=64,
            )
            for i in range(4)
        ),
    )
    return Workflow(stages=(norm, seg))


SPACE = ParamSpace.from_dict({f"p{i}": [0.0, 1.0, 2.0, 3.0] for i in range(4)})


def make_driver(calls=None, state=None, **kw):
    kw.setdefault("seed", 13)
    kw.setdefault("n_boot", 16)
    return StudyDriver(
        make_workflow(calls),
        SPACE,
        [1.0],
        objective=lambda out, i: float(out),
        state=state,
        **kw,
    )


def oneshot_round(workflow, param_sets, inputs):
    """One round as an independent study: fresh plan, cache, session."""
    uniq = list(dict.fromkeys(param_sets))
    plan = plan_study(workflow, uniq, policy="hybrid", active_paths=4)
    stream = execute_study(plan, inputs)
    y_by_ps = {}
    for rid, ps in enumerate(uniq):
        vals = [float(stream.outputs[i][rid]) for i in range(len(inputs))]
        y_by_ps[ps] = sum(vals) / len(vals)
    return [y_by_ps[ps] for ps in param_sets], stream.tasks_executed


class TestAdaptiveVsOneShot:
    def test_strictly_fewer_tasks_and_bit_identical_outputs(self):
        driver = make_driver()
        try:
            state = driver.run(max_rounds=4)
        finally:
            driver.close()
        assert len(state.rounds) >= 2
        assert {r.kind for r in state.rounds} >= {"moat", "vbd"}

        oneshot_total = 0
        for record in state.rounds:
            y, executed = oneshot_round(
                driver.workflow, record.param_sets, driver.inputs
            )
            oneshot_total += executed
            assert y == record.outputs, record.kind  # bit-identical
        # strictly fewer tasks: asserted via the measured cache counters
        assert state.tasks_executed < oneshot_total
        # every avoided execution is visible as reuse, not silently dropped
        assert state.cache.hits > 0

    def test_indices_bit_identical_to_oracle(self):
        driver = make_driver()
        try:
            state = driver.run(max_rounds=3)
        finally:
            driver.close()
        for record in state.rounds:
            y, _ = oneshot_round(driver.workflow, record.param_sets, driver.inputs)
            if record.kind == "moat":
                names = list(record.analysis["mu_star"])
                sub = ParamSpace(tuple(p for p in SPACE.params if p.name in names))
                moves = [[(int(i), p) for i, p in t] for t in record.meta["moves"]]
                res = moat_indices(sub, y, moves, n_boot=16, seed=state.seed)
                assert res.mu_star == record.analysis["mu_star"]
                assert res.mu_star_ci == record.analysis["mu_star_ci"]
            elif record.kind == "vbd":
                names = list(record.analysis["total"])
                sub = ParamSpace(tuple(p for p in SPACE.params if p.name in names))
                res = vbd_indices(
                    sub, y, record.meta["n_base"], n_boot=16, seed=state.seed
                )
                assert res.total == record.analysis["total"]
                assert res.first_order == record.analysis["first_order"]

    def test_single_persistent_manager_session(self):
        before = Manager.sessions_started
        driver = make_driver()
        try:
            driver.run(max_rounds=4)
            # the shared session must not accumulate memoised bucket
            # outputs across rounds (unbounded growth over a long study)
            assert driver.state.manager.results() == {}
        finally:
            driver.close()
        assert Manager.sessions_started - before == 1

    def test_n_boot_zero_runs_without_cis(self):
        """n_boot=0 must fall back to point-estimate pruning (analysis
        stores ci=None), not crash the policy."""
        driver = make_driver(n_boot=0)
        try:
            state = driver.run(max_rounds=3)
        finally:
            driver.close()
        assert len(state.rounds) >= 2
        assert state.rounds[0].analysis["mu_star_ci"] is None
        assert "p1" not in state.active  # pruning still happened on points

    def test_non_caching_engine_policy_rejected(self):
        with pytest.raises(ValueError, match="caching"):
            make_driver(engine_policy="stage")

    def test_resume_with_different_inputs_rejected(self, tmp_path):
        ckpt = str(tmp_path / "state.json")
        driver = make_driver(store_dir=str(tmp_path / "store"), input_keys=["a"])
        try:
            driver.run(max_rounds=1)
            driver.save(ckpt)
        finally:
            driver.close()
        st2 = StudyState.load(ckpt)
        with pytest.raises(ValueError, match="different data"):
            make_driver(state=st2, input_keys=["b"])

    def test_last_survivor_is_most_important(self):
        """When every parameter falls below the prune cutoff (min_active=0),
        the spared parameter must be the TOP of the ranking, not the tail."""
        from repro.study.state import RoundRecord

        st = StudyState(SPACE, seed=0)
        record = RoundRecord(
            index=0, kind="moat", param_sets=[], outputs=[], meta={},
            analysis={
                "mu_star": {"p0": 1.0, "p1": 0.5, "p2": 0.3, "p3": 0.2},
                # every CI-upper below 10% of max mu* -> all prunable
                "mu_star_ci": {n: (0.0, 0.01) for n in SPACE.names},
            },
        )
        decision = ScreenThenRefinePolicy(min_active=0).decide(st, record)
        assert set(SPACE.names) - set(decision.prune) == {"p0"}

    def test_failed_round_commits_nothing_to_ledger(self):
        """Ledger membership means "the store holds this output": a round
        whose execution fails permanently must not record its paths."""

        def boom(x, **kw):
            raise RuntimeError("permanent")

        norm = StageSpec(
            name="norm",
            tasks=(TaskSpec("normalize", (), fn=boom, cost=1.0, output_bytes=8),),
        )
        seg = StageSpec(
            name="seg",
            tasks=(TaskSpec("seg_t0", ("p0",), fn=boom, cost=1.0, output_bytes=8),),
        )
        wf = Workflow(stages=(norm, seg))
        space = ParamSpace.from_dict({"p0": [0.0, 1.0]})
        driver = StudyDriver(
            wf, space, [1.0], objective=lambda out, i: float(out), seed=1,
            cluster=ClusterSpec(max_attempts=1, enable_backup_tasks=False),
        )
        try:
            with pytest.raises(RuntimeError):
                driver.run_round(MoatSampler(1))
        finally:
            driver.close()
        assert len(driver.state.ledger) == 0
        assert driver.state.evaluated == {}

    def test_policy_prunes_inert_parameters(self):
        driver = make_driver(sa_policy=ScreenThenRefinePolicy(min_active=2))
        try:
            state = driver.run(max_rounds=4)
        finally:
            driver.close()
        # p0 (weight 8) and p2 (weight 2) dominate; the near-inert params go
        assert "p0" in state.active and "p2" in state.active
        assert "p1" not in state.active
        assert set(state.frozen) == set(SPACE.names) - set(state.active)

    def test_incremental_plan_reports_known_nodes(self):
        driver = make_driver()
        try:
            state = driver.run(max_rounds=3)
        finally:
            driver.close()
        later = [r for r in state.rounds if r.index > 0 and r.n_new > 0]
        assert later, "study ended before any incremental round"
        # the parameter-free norm stage is in the ledger from round 1, so
        # every later delta plan must see known prefix work
        assert any(r.planned_known > 0 for r in later)
        for r in state.rounds:
            assert r.planned_tasks >= r.planned_known >= 0


class TestResume:
    def test_resume_recomputes_zero_tasks(self, tmp_path):
        """Persisted state + content-addressed disk store: a fresh process
        re-executing round 1's exact run-list gets 100% store hits."""
        store_dir = str(tmp_path / "store")
        ckpt = str(tmp_path / "state.json")
        driver = make_driver(store_dir=store_dir)
        try:
            rec1 = driver.run_round(MoatSampler(2))
            assert rec1.tasks_executed > 0
            driver.save(ckpt)
        finally:
            driver.close()

        # "new process": fresh python objects, fresh (empty) RAM tiers
        calls2 = []
        st2 = StudyState.load(ckpt)
        assert len(st2.evaluated) > 0 and len(st2.ledger) > 0
        drv2 = make_driver(calls2, state=st2)
        try:
            # (a) re-proposing evaluated sets is elided entirely
            y, stats = drv2.evaluate(rec1.param_sets)
            assert stats["n_new"] == 0 and stats["tasks_executed"] == 0
            assert y == rec1.outputs
            assert calls2 == []
            # (b) even forcing the full plan through the engine, the store
            # rehydrates every task: zero recomputation
            plan = plan_study(
                drv2.workflow, list(dict.fromkeys(rec1.param_sets)),
                policy="hybrid", active_paths=4,
            )
            st2.epoch += 1
            stream = execute_study(
                plan, drv2.inputs,
                cache=st2.cache, manager=drv2._ensure_manager(),
                input_keys=drv2.input_keys, key_prefix=f"r{st2.epoch}:",
            )
            assert stream.tasks_executed == 0
            assert calls2 == []
            assert st2.cache.rehydrations > 0
            for rid, ps in enumerate(dict.fromkeys(rec1.param_sets)):
                assert float(stream.outputs[0][rid]) == st2.evaluated[ps]
        finally:
            drv2.close()

    def test_resumed_study_continues_rounds(self, tmp_path):
        ckpt = str(tmp_path / "state.json")
        driver = make_driver(store_dir=str(tmp_path / "store"))
        try:
            driver.run(max_rounds=1)
            driver.save(ckpt)
            phase = driver.state.phase
        finally:
            driver.close()
        st2 = StudyState.load(ckpt)
        assert st2.phase == phase
        drv2 = make_driver(state=st2)
        try:
            state = drv2.run(max_rounds=3)
        finally:
            drv2.close()
        assert len(state.rounds) >= 2

    def test_state_roundtrip_preserves_everything(self, tmp_path):
        ckpt = str(tmp_path / "state.json")
        driver = make_driver(store_dir=str(tmp_path / "store"))
        try:
            state = driver.run(max_rounds=2)
            driver.save(ckpt)
        finally:
            driver.close()
        st2 = StudyState.load(ckpt)
        assert st2.evaluated == state.evaluated
        assert st2.active == state.active and st2.frozen == state.frozen
        assert st2.best == state.best and st2.epoch == state.epoch
        assert len(st2.rounds) == len(state.rounds)
        for a, b in zip(st2.rounds, state.rounds):
            assert a.param_sets == b.param_sets
            assert a.outputs == b.outputs
            assert a.kind == b.kind and a.tasks_executed == b.tasks_executed
        assert st2.ledger.to_list() == state.ledger.to_list()


class TestTune:
    def test_coordinate_descent_finds_separable_minimum(self):
        driver = make_driver()
        try:
            best_ps, best_y = driver.tune(max_sweeps=3)
        finally:
            driver.close()
        # objective = norm(1.0) + Σ w_i p_i = 2 + Σ w_i p_i, minimised at
        # p_i = 0 for every weighted param (p1 is inert: any value ties)
        best = dict(best_ps)
        assert best["p0"] == 0.0 and best["p2"] == 0.0 and best["p3"] == 0.0
        assert best_y == 2.0

    def test_tune_reuses_prefixes(self):
        calls = []
        driver = make_driver(calls)
        try:
            driver.tune(max_sweeps=2)
            summary = driver.summary()
        finally:
            driver.close()
        # one-coordinate-at-a-time proposals share trie prefixes: measured
        # executions must undercut the naive run count substantially
        assert summary["tasks_executed"] < summary["tasks_requested"]
        assert summary["reuse_factor"] > 1.5
        assert len(calls) == sum(1 for _ in calls)  # sanity


class TestSamplers:
    def test_samplers_deterministic(self):
        s1 = StudyState(SPACE, seed=5)
        s2 = StudyState(SPACE, seed=5)
        for sampler in (MoatSampler(2), SaltelliSampler(4)):
            a, ma = sampler.propose(s1, 0)
            b, mb = sampler.propose(s2, 0)
            assert a == b and ma == mb

    def test_proposals_complete_frozen_params(self):
        st = StudyState(SPACE, seed=5)
        st.best = (SPACE.default(), 0.0)
        st.freeze(["p1", "p3"])
        sub = active_space(st)
        assert sub.names == ("p0", "p2")
        for sampler in (MoatSampler(1), SaltelliSampler(2), RefinementSampler()):
            sets, _ = sampler.propose(st, 1)
            for ps in sets:
                d = dict(ps)
                assert set(d) == set(SPACE.names)
                for name, val in st.frozen.items():
                    assert d[name] == val


@pytest.mark.slow
class TestTable1Acceptance:
    """The ISSUE 3 acceptance on the real pathology workflow."""

    def test_adaptive_moat_prune_vbd_over_table1(self):
        from repro.app import TABLE1_SPACE, synthetic_tile
        from repro.app.pipeline import build_workflow
        from repro.core import dice

        size = 24
        wf = build_workflow(size, size)
        tile = {"raw": np.asarray(synthetic_tile(size, size, seed=2))}
        ref_plan = plan_study(
            wf, [TABLE1_SPACE.default()], policy="rmsr", active_paths=1
        )
        ref_mask = execute_study(ref_plan, [tile]).outputs[0][0]["mask"]

        def objective(leaf, _i):
            return 1.0 - float(dice(leaf["mask"], ref_mask))

        driver = StudyDriver(
            wf, TABLE1_SPACE, [tile],
            objective=objective, seed=6,
            samplers={"moat": MoatSampler(1), "vbd": SaltelliSampler(2),
                      "refine": RefinementSampler()},
            n_boot=8, input_keys=["tile0"],
        )
        try:
            state = driver.run(max_rounds=2)
        finally:
            driver.close()
        kinds = [r.kind for r in state.rounds]
        assert kinds[:2] == ["moat", "vbd"]
        assert len(state.active) < TABLE1_SPACE.dim  # screening pruned

        # one-shot oracle: same rounds as independent studies
        oneshot_total = 0
        for record in state.rounds:
            uniq = list(dict.fromkeys(record.param_sets))
            plan = plan_study(wf, uniq, policy="hybrid", active_paths=4)
            stream = execute_study(plan, [tile])
            oneshot_total += stream.tasks_executed
            y_by_ps = {
                ps: 1.0 - float(dice(stream.outputs[0][rid]["mask"], ref_mask))
                for rid, ps in enumerate(uniq)
            }
            y = [y_by_ps[ps] for ps in record.param_sets]
            assert y == record.outputs, record.kind  # bit-identical runs

            # …and therefore bit-identical indices
            if record.kind == "moat":
                sub = ParamSpace(
                    tuple(p for p in TABLE1_SPACE.params
                          if p.name in record.analysis["mu_star"])
                )
                moves = [[(int(i), p) for i, p in t] for t in record.meta["moves"]]
                res = moat_indices(sub, y, moves, n_boot=8, seed=state.seed)
                assert res.mu_star == record.analysis["mu_star"]
            if record.kind == "vbd":
                sub = ParamSpace(
                    tuple(p for p in TABLE1_SPACE.params
                          if p.name in record.analysis["total"])
                )
                res = vbd_indices(sub, y, record.meta["n_base"],
                                  n_boot=8, seed=state.seed)
                assert res.total == record.analysis["total"]

        # strictly fewer tasks, visible through the measured counters
        assert state.tasks_executed < oneshot_total

"""Fault injection for the persistent Manager + streaming executor.

The streaming claims under fire: transient task failures, Workers dying
mid-lease (heartbeat expiry), and injected stragglers (backup tasks racing
originals) during a multi-input `execute_study` must leave every output
bit-identical to the fault-free oracle, with `retries` /
`backups_launched` / cache-hit accounting consistent — in particular no
double-count when a backup and its original both complete (first completion
wins; only the winner's counters and callback fire).
"""

import os
import pathlib
import random
import signal
import threading
import time

import pytest

from repro.core import StageSpec, TaskSpec, Workflow
from repro.engine import ClusterSpec, execute_study, plan_study
from repro.runtime import ProcessRpcBackend
from repro.runtime.manager import Manager, WorkItem

from study_gen import naive_outputs, random_param_sets, random_workflow

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


class Injector:
    """Thread-safe fault switchboard consulted by instrumented task fns.
    Inactive while the oracle runs, armed only for the streaming run."""

    def __init__(self):
        self.lock = threading.Lock()
        self.active = False
        self.failures_left = 0
        self.sleep_once_seconds = 0.0
        self.injected_failures = 0

    def maybe_fault(self):
        with self.lock:
            if not self.active:
                return
            if self.failures_left > 0:
                self.failures_left -= 1
                self.injected_failures += 1
                raise RuntimeError("injected transient fault")
            if self.sleep_once_seconds > 0.0:
                s, self.sleep_once_seconds = self.sleep_once_seconds, 0.0
            else:
                return
        time.sleep(s)  # straggle outside the lock


def instrumented_workflow(rng, injector):
    wf, names, cards = random_workflow(rng, max_stages=2)

    def wrap(fn):
        def wrapped(x, **kw):
            injector.maybe_fault()
            return fn(x, **kw)

        return wrapped

    stages = tuple(
        StageSpec(
            name=s.name,
            tasks=tuple(
                TaskSpec(
                    name=t.name,
                    param_names=t.param_names,
                    fn=wrap(t.fn),
                    cost=t.cost,
                    output_bytes=t.output_bytes,
                )
                for t in s.tasks
            ),
        )
        for s in wf.stages
    )
    return Workflow(stages=stages), wf, names, cards


@pytest.mark.parametrize("policy", ["stage", "hybrid"])
def test_transient_failures_leave_outputs_unchanged(policy):
    inj = Injector()
    rng = random.Random(501)
    wf, clean_wf, names, cards = instrumented_workflow(rng, inj)
    sets = random_param_sets(rng, names, cards, 12)
    inputs = [3, 8, 21]
    oracles = [naive_outputs(clean_wf, sets, x) for x in inputs]

    plan = plan_study(wf, sets, policy=policy, max_bucket_size=3)
    inj.failures_left = 3
    inj.active = True
    try:
        stream = execute_study(
            plan,
            inputs,
            cluster=ClusterSpec(
                n_workers=2, max_attempts=6, enable_backup_tasks=False
            ),
        )
    finally:
        inj.active = False
    assert inj.injected_failures == 3
    for i in range(len(inputs)):
        assert stream.outputs[i] == oracles[i], i
    # each injected task fault fails exactly one bucket attempt → one retry
    assert stream.retries == 3
    assert stream.backups_launched == 0
    # winner-only accounting: retried replays never double-count
    assert (
        stream.tasks_executed + stream.cache_hits
        == plan.tasks_executed * len(inputs)
    )


def test_permanent_failure_aborts_study_with_original_error():
    inj = Injector()
    rng = random.Random(502)
    wf, _, names, cards = instrumented_workflow(rng, inj)
    sets = random_param_sets(rng, names, cards, 6)
    plan = plan_study(wf, sets, policy="stage")
    inj.failures_left = 10**9
    inj.active = True
    try:
        with pytest.raises(RuntimeError, match="injected transient fault"):
            execute_study(
                plan,
                [1, 2],
                cluster=ClusterSpec(
                    n_workers=2, max_attempts=2, enable_backup_tasks=False
                ),
            )
    finally:
        inj.active = False


def test_injected_straggler_backup_no_double_count():
    """One bucket attempt straggles (sleeps); idle Workers clone it. First
    completion wins: outputs stay bit-identical and per-task accounting is
    counted exactly once even when original and backup both finish."""
    inj = Injector()
    rng = random.Random(503)
    wf, clean_wf, names, cards = instrumented_workflow(rng, inj)
    sets = random_param_sets(rng, names, cards, 16)
    inputs = [5, 9]
    oracles = [naive_outputs(clean_wf, sets, x) for x in inputs]

    plan = plan_study(wf, sets, policy="stage", max_bucket_size=2)
    inj.sleep_once_seconds = 0.6
    inj.active = True
    try:
        stream = execute_study(
            plan,
            inputs,
            cluster=ClusterSpec(
                n_workers=3, straggler_factor=1.5, max_attempts=4
            ),
        )
    finally:
        inj.active = False
    for i in range(len(inputs)):
        assert stream.outputs[i] == oracles[i], i
    # every run routed exactly once per input, regardless of raced backups
    for i in range(len(inputs)):
        assert sorted(stream.outputs[i]) == list(range(len(sets)))
    assert (
        stream.tasks_executed + stream.cache_hits
        == plan.tasks_executed * len(inputs)
    )


class TestPersistentManagerSessions:
    def test_submit_while_running_chained_callbacks_drain(self):
        """drain() must not return while a completion callback is still
        submitting downstream work — the per-input stage-edge pattern."""
        mgr = Manager(enable_backup_tasks=False)
        seen = []

        def cb(key, value):
            seen.append((key, value))
            if value < 5:
                mgr.submit(
                    WorkItem(
                        key=f"chain{value + 1}",
                        fn=lambda v=value: v + 1,
                        callback=cb,
                    )
                )

        mgr.start(2)
        try:
            mgr.submit(WorkItem(key="chain0", fn=lambda: 0, callback=cb))
            mgr.drain()
            assert sorted(mgr.results().values()) == [0, 1, 2, 3, 4, 5]
            assert len(seen) == 6
            # session persists: a second wave reuses the same Workers
            before = Manager.sessions_started
            mgr.submit(WorkItem(key="late", fn=lambda: "ok"))
            mgr.drain()
            assert mgr.results()["late"] == "ok"
            assert Manager.sessions_started == before  # no new session
        finally:
            mgr.close()
        with pytest.raises(RuntimeError):
            mgr.submit(WorkItem(key="after-close", fn=lambda: 1))

    def test_callback_fires_exactly_once_per_key(self):
        counts = {}
        lock = threading.Lock()

        def cb(key, value):
            with lock:
                counts[key] = counts.get(key, 0) + 1

        mgr = Manager(straggler_factor=0.5, max_attempts=4)
        release = threading.Event()

        def slow():
            if not release.is_set():
                release.set()
                time.sleep(0.5)
                return "slow"
            return "fast"

        for i in range(6):
            mgr.submit(
                WorkItem(key=f"q{i}", fn=lambda: time.sleep(0.01) or "q", callback=cb)
            )
        mgr.submit(WorkItem(key="strag", fn=slow, callback=cb))
        out = mgr.run(3, expected=7)
        assert out["strag"] in ("fast", "slow")
        assert all(c == 1 for c in counts.values()), counts
        assert set(counts) == {f"q{i}" for i in range(6)} | {"strag"}

    def test_heartbeat_expiry_recovers_dead_worker_lease(self):
        """A lease whose Worker misses the heartbeat deadline is re-enqueued
        and completed by a live Worker; the zombie's late completion is
        deduped by first-completion-wins."""
        mgr = Manager(
            heartbeat_timeout=0.05, enable_backup_tasks=False, max_attempts=3
        )
        first = threading.Event()

        def dead_then_alive():
            if not first.is_set():
                first.set()
                time.sleep(0.5)  # "dead" well past the 50ms deadline
                return "zombie"
            return "alive"

        mgr.submit(WorkItem(key="k", fn=dead_then_alive))
        for i in range(3):
            mgr.submit(WorkItem(key=f"pad{i}", fn=lambda: "p"))
        out = mgr.run(2, expected=4)
        assert out["k"] in ("alive", "zombie")
        assert mgr.heartbeat_expiries >= 1
        assert mgr.retries >= 1


# ---------------------------------------------------------------------------
# Work stealing under fire (ISSUE 7): steal storms + expired leases +
# killed workers must preserve exactly-once settlement and callbacks
# ---------------------------------------------------------------------------

# ``block=1, steal_min=1`` delegates one item at a time and lets every idle
# pump raid every peer — the maximum-contention "steal storm" topology. Any
# double-lease, lost item, or double-settlement shows up as a wrong count.
STORM = "fanout={f},block=1,steal_min=1"


def _hier_hang_until_killed(marker_dir):
    """Spawn-picklable: first execution in the fleet records its pid and
    hangs for the test to SIGKILL; retries return fast."""
    marker = pathlib.Path(marker_dir) / "pid"
    if not marker.exists():
        marker.write_text(str(os.getpid()))
        time.sleep(60.0)
        return "hung"
    return "fast"


def _hier_quick(tag):
    time.sleep(0.01)
    return f"q-{tag}"


def test_steal_storm_with_expired_leases_exactly_once():
    """Manager-level storm: 40 keys over 4 sub-pumps with one-item blocks,
    aggressive backups (straggler_factor 0.5), one worker that goes dead
    past the heartbeat deadline mid-lease, and transient failures. Every
    key must settle exactly once — one callback, one result — and the
    storm must actually steal (the topology guarantees imbalance)."""
    counts = {}
    lock = threading.Lock()

    def cb(key, value):
        with lock:
            counts[key] = counts.get(key, 0) + 1

    first = threading.Event()

    def dead_then_alive():
        if not first.is_set():
            first.set()
            time.sleep(0.4)  # well past the 50ms heartbeat deadline
            return "zombie"
        return "alive"

    flaky_left = [2]

    def flaky():
        with lock:
            if flaky_left[0] > 0:
                flaky_left[0] -= 1
                raise RuntimeError("injected transient fault")
        return "ok"

    mgr = Manager(
        heartbeat_timeout=0.05,
        straggler_factor=0.5,
        max_attempts=6,
        hierarchy=STORM.format(f=4),
    )
    mgr.submit(WorkItem(key="dead", fn=dead_then_alive, callback=cb))
    mgr.submit(WorkItem(key="flaky", fn=flaky, callback=cb))
    for i in range(38):
        mgr.submit(
            WorkItem(
                key=f"k{i}",
                fn=lambda i=i: time.sleep(0.005) or i * 3,
                callback=cb,
            )
        )
    out = mgr.run(4, expected=40)
    stats = mgr.scheduler_stats()
    assert len(out) == 40
    assert out["dead"] in ("alive", "zombie")
    assert out["flaky"] == "ok"
    assert all(out[f"k{i}"] == i * 3 for i in range(38))
    assert all(c == 1 for c in counts.values()), {
        k: c for k, c in counts.items() if c != 1
    }
    assert set(counts) == set(out)
    assert stats["mode"] == "hierarchical" and stats["fanout"] == 4
    assert mgr.heartbeat_expiries >= 1
    assert mgr.retries >= 3  # 2 injected faults + the expired lease


def _check_streaming_storm(seed, fanout, failures, straggle):
    """The storm property: streaming under a steal storm + transient
    failures + an optional injected straggler (backup clones racing
    originals) leaves outputs bit-identical to the fault-free oracle with
    the exactly-once accounting identity intact."""
    inj = Injector()
    rng = random.Random(seed)
    wf, clean_wf, names, cards = instrumented_workflow(rng, inj)
    sets = random_param_sets(rng, names, cards, rng.randint(2, 12))
    inputs = [rng.randrange(1 << 40) for _ in range(2)]
    oracles = [naive_outputs(clean_wf, sets, x) for x in inputs]
    plan = plan_study(wf, sets, policy="hybrid", max_bucket_size=2)

    inj.failures_left = failures
    if straggle:
        inj.sleep_once_seconds = 0.3
    inj.active = True
    try:
        stream = execute_study(
            plan,
            inputs,
            cluster=ClusterSpec(
                n_workers=4, max_attempts=8, straggler_factor=1.5
            ),
            hierarchy=STORM.format(f=fanout),
        )
    finally:
        inj.active = False
    for i in range(len(inputs)):
        assert stream.outputs[i] == oracles[i], i
    assert (
        stream.tasks_executed + stream.cache_hits
        == plan.tasks_executed * len(inputs)
    )
    assert stream.scheduler["fanout"] == fanout


@pytest.mark.parametrize("seed,fanout,failures,straggle", [
    (601, 2, 0, False),
    (602, 3, 2, False),
    (603, 4, 3, True),
    (604, 4, 1, True),
])
def test_streaming_storm_bit_identical(seed, fanout, failures, straggle):
    """Seeded instances of the storm property (always run; the hypothesis
    layer below explores the same contract when hypothesis is installed)."""
    _check_streaming_storm(seed, fanout, failures, straggle)


if HAVE_HYPOTHESIS:

    class TestHypothesisStealStorm:
        @given(
            seed=st.integers(min_value=0, max_value=2**20),
            fanout=st.sampled_from([2, 3, 4]),
            failures=st.integers(min_value=0, max_value=3),
            straggle=st.booleans(),
        )
        @settings(max_examples=10, deadline=None)
        def test_streaming_storm_bit_identical(
            self, seed, fanout, failures, straggle
        ):
            _check_streaming_storm(seed, fanout, failures, straggle)


def test_sigkilled_worker_under_hierarchy_settles_exactly_once(tmp_path):
    """fanout=2 over RPC worker processes, one worker SIGKILLed mid-lease:
    the leader's heartbeat expiry re-enqueues the lease, a sub-pump whose
    shard lost its only worker goes idle, and the surviving shard (via
    redistribution/stealing) completes everything — every key exactly once."""
    marker_dir = tmp_path / "marker"
    marker_dir.mkdir()
    counts = {}
    lock = threading.Lock()

    def cb(key, value):
        with lock:
            counts[key] = counts.get(key, 0) + 1

    mgr = Manager(
        backend=ProcessRpcBackend(
            store_dir=str(tmp_path / "store"), heartbeat_interval=0.05
        ),
        enable_backup_tasks=False,
        max_attempts=3,
        hierarchy=STORM.format(f=2),
    )
    mgr.start(2)
    try:
        mgr.submit(
            WorkItem(
                key="victim",
                spec=("call", _hier_hang_until_killed, (str(marker_dir),), {}),
                callback=cb,
            )
        )
        for i in range(4):
            mgr.submit(
                WorkItem(
                    key=f"pad{i}",
                    spec=("call", _hier_quick, (i,), {}),
                    callback=cb,
                )
            )
        pid_file = marker_dir / "pid"
        deadline = time.monotonic() + 30
        while not pid_file.exists():
            assert time.monotonic() < deadline, "hang task never started"
            time.sleep(0.02)
        os.kill(int(pid_file.read_text()), signal.SIGKILL)
        mgr.drain()
        out = mgr.results()
        assert out["victim"] == "fast"  # re-run by the SURVIVING worker
        for i in range(4):
            assert out[f"pad{i}"] == f"q-{i}"
        assert all(c == 1 for c in counts.values()), counts
        assert set(counts) == set(out)
        assert mgr.heartbeat_expiries >= 1
        assert mgr.scheduler_stats()["mode"] == "hierarchical"
    finally:
        mgr.close()


def test_streaming_pipelines_across_inputs():
    """No global stage barrier: a fast input must finish its LAST stage
    while a slow input is still stuck in an earlier stage."""
    log = []
    lock = threading.Lock()

    def mark(tag, i, x):
        with lock:
            log.append((tag, i, time.monotonic()))
        return x

    def s0_fn(state, **kw):
        i, x = state
        return (i, mark("s0", i, x + 1))

    def s1_fn(state, **kw):
        i, x = state
        if i == 0:
            time.sleep(0.4)  # input 0 straggles in stage 1
        return (i, mark("s1", i, x * 2))

    wf = Workflow(
        stages=(
            StageSpec(name="a", tasks=(TaskSpec("t0", (), fn=s0_fn),)),
            StageSpec(name="b", tasks=(TaskSpec("t1", (), fn=s1_fn),)),
        )
    )
    plan = plan_study(wf, [()], policy="stage")
    stream = execute_study(
        plan,
        [(0, 10), (1, 20)],
        cluster=ClusterSpec(n_workers=2, enable_backup_tasks=False),
    )
    assert stream.outputs[0][0] == (0, 22)
    assert stream.outputs[1][0] == (1, 42)
    t_done = {i: max(t for tag, j, t in log if j == i and tag == "s1") for i in (0, 1)}
    assert t_done[1] < t_done[0], "fast input should overtake the straggler"

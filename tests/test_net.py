"""Multi-host substrate tests (ISSUE 8, DESIGN.md §16): the S3-style
object store + the store tier over it, and the raw-socket WorkerBackend.

Three layers, matching the subsystem's:

* **ObjectStore contract** — both shipped implementations satisfy the
  same get/put/head/list/delete + conditional-create semantics (the
  LocalFS reference via atomic ``os.link``, the in-memory fake via a
  lock), because the tier above relies on ``put_if_absent`` AS the
  cross-host coordination primitive.
* **ObjectBackedStore** — the §12 entry protocol over objects: bit-exact
  hydration, conditional-write dedup across independent mounts (no flock
  anywhere), quarantine-on-corrupt self-healing, the commit-record crash
  window healing on peer re-commit, and spec round-trips through
  ``mount_store``.
* **SocketBackend faults** — protocol-version mismatch rejected at the
  handshake; a mid-lease TCP disconnect re-enqueues the lease to a
  survivor while the disconnected worker reconnects under its old id; and
  the ISSUE-8 acceptance scenario: a loopback fleet over the object tier
  (no shared working directory beyond the store root) survives one
  SIGKILLed AND one disconnected worker with exactly-once callbacks, then
  runs a study bit-identical to the thread backend in the same degraded
  session.

Task functions are module-level and data-only where they cross the spawn
boundary (socket workers re-import this module in fresh interpreters).
"""

import os
import pathlib
import random
import signal
import socket
import threading
import time

import numpy as np
import pytest

from repro.engine import ClusterSpec, execute_study, plan_study
from repro.runtime import (
    InMemoryObjectStore,
    LocalFSObjectStore,
    Manager,
    ObjectBackedStore,
    SocketBackend,
    WorkItem,
    mount_store,
    socket_flag_kwargs,
)
from repro.runtime.net import PROTOCOL_VERSION, SocketConn, parse_address
from repro.runtime.storage import stable_key
from repro.runtime.transport import _recv_frame, _send_frame

from study_gen import (
    mix_study_build,
    naive_outputs,
    random_layout,
    random_param_sets,
    workflow_from_layout,
)

pytestmark = pytest.mark.filterwarnings("ignore::ResourceWarning")


# ---------------------------------------------------------------------------
# Spawn-picklable task functions
# ---------------------------------------------------------------------------


def _quick(tag):
    time.sleep(0.01)
    return f"q-{tag}"


def _hang_until_killed(marker_dir):
    """First execution anywhere in the fleet: record our pid and hang (the
    test SIGKILLs us). Later executions return fast — the survivor path."""
    marker = pathlib.Path(marker_dir) / "kill_pid"
    if not marker.exists():
        # write-then-rename: the test polls for existence, so the pid must
        # be complete the instant the path appears
        tmp = marker.with_suffix(".tmp")
        tmp.write_text(str(os.getpid()))
        os.replace(tmp, marker)
        time.sleep(60.0)
        return "hung"
    return "fast"


def _slow_first(marker_dir):
    """First execution sleeps long enough for the test to cut its worker's
    connection mid-lease; the survivor's re-run returns immediately."""
    marker = pathlib.Path(marker_dir) / "slow"
    try:
        fd = os.open(marker, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
        os.close(fd)
    except FileExistsError:
        return "done"
    time.sleep(2.0)
    return "done"


# ---------------------------------------------------------------------------
# ObjectStore contract — both implementations
# ---------------------------------------------------------------------------


@pytest.fixture(params=["localfs", "memory"])
def objstore(request, tmp_path):
    if request.param == "localfs":
        return LocalFSObjectStore(str(tmp_path / "root"))
    return InMemoryObjectStore()


class TestObjectStoreContract:
    def test_put_get_head_delete(self, objstore):
        assert objstore.get("a/b") is None
        assert objstore.head("a/b") is None
        etag = objstore.put("a/b", b"hello")
        assert objstore.get("a/b") == b"hello"
        meta = objstore.head("a/b")
        assert meta.size == 5 and meta.etag == etag
        assert objstore.delete("a/b") is True
        assert objstore.delete("a/b") is False
        assert objstore.get("a/b") is None

    def test_put_replaces_whole_object(self, objstore):
        objstore.put("k", b"v1")
        e2 = objstore.put("k", b"v2-longer")
        assert objstore.get("k") == b"v2-longer"
        assert objstore.head("k").etag == e2

    def test_put_if_absent_first_writer_wins(self, objstore):
        created, etag1 = objstore.put_if_absent("k", b"first")
        assert created is True
        created, etag2 = objstore.put_if_absent("k", b"second")
        assert created is False
        assert etag2 == etag1  # the survivor's etag, not the loser's
        assert objstore.get("k") == b"first"

    def test_put_if_absent_after_delete_creates(self, objstore):
        objstore.put_if_absent("k", b"v")
        objstore.delete("k")
        created, _ = objstore.put_if_absent("k", b"v2")
        assert created is True
        assert objstore.get("k") == b"v2"

    def test_list_is_sorted_prefix_scan(self, objstore):
        for k in ("entries/b", "entries/a", "keys/a", "solo"):
            objstore.put(k, b"x")
        assert objstore.list("entries/") == ["entries/a", "entries/b"]
        assert objstore.list() == ["entries/a", "entries/b", "keys/a", "solo"]

    def test_illegal_keys_rejected(self, objstore):
        for bad in ("", "/abs", "a/../b"):
            with pytest.raises(ValueError):
                objstore.put(bad, b"x")


def test_localfs_tmp_siblings_are_not_objects(tmp_path):
    store = LocalFSObjectStore(str(tmp_path))
    store.put("entries/x", b"data")
    # a crashed writer's tmp sibling must not appear as an object
    (tmp_path / "entries" / ".x.crashed").write_bytes(b"partial")
    assert store.list() == ["entries/x"]
    assert store.get("entries/x") == b"data"


# ---------------------------------------------------------------------------
# ObjectBackedStore: §12 entry protocol over objects
# ---------------------------------------------------------------------------


class TestObjectBackedStore:
    def test_bit_exact_round_trip_across_mounts(self, tmp_path):
        spec = f"obj:{tmp_path / 'root'}"
        s1 = mount_store(spec, 1 << 20, writer_id="w1")
        assert isinstance(s1, ObjectBackedStore)
        arr = np.arange(16, dtype=np.int64).reshape(4, 4)
        s1.put("arr", arr)
        s1.put("scalars", {"n": 2, "s": "x", "f": 0.5})
        s1.persist_all()
        # an INDEPENDENT mount over the same root (no shared state)
        s2 = mount_store(spec, 1 << 20, writer_id="w2")
        np.testing.assert_array_equal(np.asarray(s2.get("arr")), arr)
        d = s2.get("scalars")
        assert d == {"n": 2, "s": "x", "f": 0.5}
        assert type(d["n"]) is int and type(d["s"]) is str
        assert s2.committed_keys() == {"arr", "scalars"}

    def test_conditional_write_dedup_across_writers(self, tmp_path):
        spec = f"obj:{tmp_path / 'root'}"
        s1 = mount_store(spec, 1 << 20, writer_id="w1")
        s1.put("x", np.ones(8, np.float32))
        s1.persist("x")
        s2 = mount_store(spec, 1 << 20, writer_id="w2")
        s2.put("x", np.ones(8, np.float32))
        s2.persist("x")
        assert s2.dedup_writes == 1  # lost the conditional create, no lock
        assert s1.dedup_writes == 0
        # re-persist through the same instance is a no-op, not a dedup
        s2.persist("x")
        assert s2.dedup_writes == 1

    def test_quarantine_on_corrupt_then_self_heal(self):
        fake = InMemoryObjectStore()
        s1 = ObjectBackedStore(1 << 20, fake, writer_id="w1")
        s1.put("x", np.ones(8, np.float32))
        s1.persist("x")
        sha = stable_key("x")
        fake.corrupt(f"entries/{sha}")
        s2 = ObjectBackedStore(1 << 20, fake, writer_id="w2")
        assert s2.get("x") is None  # footer check refused the bytes
        assert s2.corrupt == 1
        # evidence preserved, entry + commit record removed
        assert fake.list("quarantine/") != []
        assert fake.head(f"entries/{sha}") is None
        assert s2.committed_keys() == set()
        # the next writer self-heals
        s2.put("x", np.ones(8, np.float32))
        s2.persist("x")
        np.testing.assert_array_equal(
            np.asarray(ObjectBackedStore(1 << 20, fake).get("x")),
            np.ones(8, np.float32),
        )
        assert s2.committed_keys() == {"x"}

    def test_crash_window_entry_without_record_heals_on_recommit(self, tmp_path):
        """A writer killed between the entry put and the key-record put
        leaves a servable entry missing from committed_keys(); any peer
        re-committing the key restores the record (entries stay ground
        truth, the key index stays advisory — the manifest's contract)."""
        spec = f"obj:{tmp_path / 'root'}"
        s1 = mount_store(spec, 1 << 20, writer_id="w1")
        s1.put("x", np.ones(4, np.float32))
        s1.persist("x")
        sha = stable_key("x")
        s1.objstore.delete(f"keys/{sha}")  # simulate the torn commit
        s2 = mount_store(spec, 1 << 20, writer_id="w2")
        assert s2.committed_keys() == set()
        assert s2.get("x") is not None  # the entry itself still serves
        s2.put("x", np.ones(4, np.float32))
        s2.persist("x")  # dedup-loses the entry, re-commits the record
        assert s2.dedup_writes == 1
        assert s2.committed_keys() == {"x"}

    def test_transient_put_failure_surfaces_then_recovers(self):
        fake = InMemoryObjectStore()
        s = ObjectBackedStore(1 << 20, fake)
        s.put("x", np.ones(4, np.float32))
        fake.fail_puts_once = True
        with pytest.raises(OSError):
            s.persist("x")
        s.persist("x")  # the retry lands
        assert s.committed_keys() == {"x"}

    def test_manifest_records_shape(self, tmp_path):
        s = mount_store(f"obj:{tmp_path / 'root'}", 1 << 20)
        s.put("k", np.zeros(4, np.float32))
        s.persist("k")
        records = s.manifest_records()
        assert set(records) == {"k"}
        assert records["k"]["sha"] == stable_key("k")
        assert records["k"]["len"] > 0

    def test_mount_store_spec_round_trip(self, tmp_path):
        spec = f"obj:{tmp_path / 'root'}"
        s = mount_store(spec, 1 << 20)
        assert s.disk_dir == spec  # what StudyState.save records
        again = mount_store(s.disk_dir, 1 << 20)
        assert isinstance(again, ObjectBackedStore)
        plain = mount_store(str(tmp_path / "plain"), 1 << 20)
        assert plain.disk_dir == str(tmp_path / "plain")
        with pytest.raises(ValueError):
            mount_store("obj:", 1 << 20)


# ---------------------------------------------------------------------------
# Spec grammar
# ---------------------------------------------------------------------------


class TestSocketSpecGrammar:
    def test_address_flags_and_tunables(self):
        assert socket_flag_kwargs("socket") == {}
        kw = socket_flag_kwargs("socket[0.0.0.0:7077,external,-async]")
        assert kw == {
            "bind": "0.0.0.0:7077",
            "spawn_workers": False,
            "async_commit": False,
        }
        kw = socket_flag_kwargs("socket[none,batch,max_batch=4,store=obj:/d/s]")
        assert kw["batch_frames"] is True
        assert kw["warm_plans"] is False and kw["async_commit"] is False
        assert kw["max_batch"] == 4 and kw["store"] == "obj:/d/s"

    def test_rejections(self):
        with pytest.raises(ValueError):
            socket_flag_kwargs("socket[shm]")  # shm cannot cross hosts
        with pytest.raises(ValueError):
            socket_flag_kwargs("socket[bogus]")
        with pytest.raises(ValueError):
            socket_flag_kwargs("socket[unknown=1]")
        with pytest.raises(ValueError):
            socket_flag_kwargs("process[batch]")
        with pytest.raises(ValueError):
            parse_address("no-port")


# ---------------------------------------------------------------------------
# SocketBackend: handshake + network faults
# ---------------------------------------------------------------------------


def _mk_socket_manager(tmp_path, n_workers=2, *, build=None, build_kwargs=None,
                       **mgr_kwargs):
    mgr = Manager(
        backend=SocketBackend(
            build=build,
            build_kwargs=build_kwargs,
            store=f"obj:{tmp_path / 'objroot'}",
            heartbeat_interval=0.05,
        ),
        **mgr_kwargs,
    )
    mgr.start(n_workers)
    return mgr


def test_protocol_version_mismatch_rejected_at_handshake(tmp_path):
    mgr = _mk_socket_manager(tmp_path, 1)
    backend = mgr.backend
    try:
        host, port = parse_address(backend.address)
        conn = SocketConn(socket.create_connection((host, port), timeout=5))
        try:
            _send_frame(conn, threading.Lock(), {
                "t": "register", "proto": PROTOCOL_VERSION + 99,
                "wid": None, "pid": os.getpid(), "caps": {},
            })
            assert conn.poll(5.0)
            reply = _recv_frame(conn)
        finally:
            conn.close()
        assert reply["t"] == "reject"
        assert "protocol version mismatch" in reply["reason"]
        assert reply["proto"] == PROTOCOL_VERSION  # tells the worker what to speak
        assert backend.stats()["leader"]["rejects"] == 1
        # the refused dialer never became a worker
        assert len([s for s in backend.heartbeat_view().values() if s.alive]) == 1
        # ...and the fleet still works
        mgr.submit(WorkItem(key="k", spec=("call", _quick, ("x",), {})))
        mgr.drain()
        assert mgr.results()["k"] == "q-x"
    finally:
        mgr.close()
        backend.cleanup()


def _wait_for(pred, timeout, what):
    deadline = time.monotonic() + timeout
    while not pred():
        assert time.monotonic() < deadline, f"timed out waiting for {what}"
        time.sleep(0.02)


def test_mid_lease_disconnect_survivor_completes_and_worker_reconnects(tmp_path):
    """Cut the TCP connection under a running lease: the lease rides a
    tombstone row into the Manager's dead-worker expiry and a SURVIVOR
    completes it (exactly-once callback), while the disconnected worker
    re-registers under its old id with backoff — the fleet ends at full
    strength with the same worker-id set."""
    marker_dir = tmp_path / "marker"
    marker_dir.mkdir()
    fired = {}
    mgr = _mk_socket_manager(
        tmp_path, 2, enable_backup_tasks=False, max_attempts=3
    )
    backend = mgr.backend
    wids_before = sorted(
        wid for wid, st in backend.heartbeat_view().items() if st.alive
    )
    try:
        def cb(key, value):
            fired[key] = fired.get(key, 0) + 1

        mgr.submit(WorkItem(key="victim", callback=cb,
                            spec=("call", _slow_first, (str(marker_dir),), {})))
        for i in range(3):
            mgr.submit(WorkItem(key=f"pad{i}", callback=cb,
                                spec=("call", _quick, (i,), {})))

        def victim_holder():
            for wid, st in backend.heartbeat_view().items():
                if wid >= 0 and st.alive and any(
                    lid.startswith("victim#") for lid in st.inflight
                ):
                    return wid
            return None

        _wait_for(lambda: victim_holder() is not None, 15, "victim leased")
        wid = victim_holder()
        assert backend.disconnect(wid) is True  # the modelled partition
        mgr.drain()
        out = mgr.results()
        assert out["victim"] == "done"  # completed by the survivor
        for i in range(3):
            assert out[f"pad{i}"] == f"q-{i}"
        assert all(n == 1 for n in fired.values()), fired  # exactly once
        assert len(fired) == 4
        assert mgr.retries >= 1 or mgr.heartbeat_expiries >= 1
        # the partitioned worker re-registers under the SAME id
        _wait_for(
            lambda: backend.stats()["leader"]["reconnects"] >= 1,
            20, "worker reconnect",
        )
        _wait_for(
            lambda: sorted(
                w for w, st in backend.heartbeat_view().items()
                if w >= 0 and st.alive
            ) == wids_before,
            20, "fleet back to full strength",
        )
        # and serves new work after reconnecting
        mgr.submit(WorkItem(key="after", spec=("call", _quick, ("z",), {})))
        mgr.drain()
        assert mgr.results()["after"] == "q-z"
    finally:
        mgr.close()
        backend.cleanup()


def test_acceptance_fleet_survives_sigkill_and_disconnect(tmp_path):
    """ISSUE 8 acceptance: ≥2 workers joined by TCP against an
    ObjectStore-backed store — no shared working directory beyond the
    store root — survive one SIGKILLed and one DISCONNECTED worker with
    exactly-once callbacks, and the same degraded session then executes a
    study bit-identical to the thread backend."""
    rng = random.Random(816)
    layout, names, cards = random_layout(rng, max_stages=3)
    wf = workflow_from_layout(layout)
    sets = random_param_sets(rng, names, cards, 8)
    inputs = [3, 8, 21]
    oracles = [naive_outputs(wf, sets, x) for x in inputs]

    marker_dir = tmp_path / "marker"
    marker_dir.mkdir()
    fired = {}
    mgr = _mk_socket_manager(
        tmp_path, 3,
        build=mix_study_build,
        build_kwargs={"layout": layout, "inputs": inputs},
        enable_backup_tasks=False,
        max_attempts=3,
    )
    backend = mgr.backend
    try:
        def cb(key, value):
            fired[key] = fired.get(key, 0) + 1

        mgr.submit(WorkItem(key="killed", callback=cb,
                            spec=("call", _hang_until_killed,
                                  (str(marker_dir),), {})))
        mgr.submit(WorkItem(key="cut", callback=cb,
                            spec=("call", _slow_first, (str(marker_dir),), {})))
        for i in range(4):
            mgr.submit(WorkItem(key=f"pad{i}", callback=cb,
                                spec=("call", _quick, (i,), {})))

        pid_file = marker_dir / "kill_pid"
        _wait_for(pid_file.exists, 30, "hang task to start")
        victim_pid = int(pid_file.read_text())

        def cut_holder():
            for wid, st in backend.heartbeat_view().items():
                if wid >= 0 and st.alive and any(
                    lid.startswith("cut#") for lid in st.inflight
                ):
                    return wid
            return None

        _wait_for(lambda: cut_holder() is not None, 15, "cut task leased")
        cut_wid = cut_holder()
        os.kill(victim_pid, signal.SIGKILL)  # fault 1: a dead host
        assert backend.disconnect(cut_wid)   # fault 2: a network partition
        mgr.drain()
        out = mgr.results()
        assert out["killed"] == "fast"  # re-run by a surviving worker
        assert out["cut"] == "done"
        for i in range(4):
            assert out[f"pad{i}"] == f"q-{i}"
        assert all(n == 1 for n in fired.values()), fired  # exactly once
        assert len(fired) == 6
        # the killed worker stays dead; the partitioned one rejoins
        _wait_for(
            lambda: sum(
                1 for w, st in backend.heartbeat_view().items()
                if w >= 0 and st.alive
            ) == 2,
            20, "fleet to settle at two live workers",
        )
        # the SAME degraded session now runs a study — bit-identical to
        # the thread backend (= the naive oracle)
        plan = plan_study(wf, sets, policy="hybrid", max_bucket_size=3)
        thread_stream = execute_study(
            plan, inputs,
            cluster=ClusterSpec(n_workers=2, enable_backup_tasks=False),
        )
        sock_stream = execute_study(plan, inputs, manager=mgr, key_prefix="a:")
        assert sock_stream.backend == "socket"
        for i in range(len(inputs)):
            assert sock_stream.outputs[i] == oracles[i], i
            assert sock_stream.outputs[i] == thread_stream.outputs[i], i
        # everything durable lives under the object root: entries +
        # commit records, with the session's transient rpc: payloads
        # purged at close (asserted after close below)
        store = backend.store
        assert any(k.startswith("rpc:") for k in store.committed_keys())
    finally:
        mgr.close()
    try:
        purged = [k for k in backend.store.committed_keys()
                  if k.startswith("rpc:")]
        assert purged == []
    finally:
        backend.cleanup()


def test_worker_ids_sticky_and_tombstones_expire_from_view(tmp_path):
    """White-box: after a reconnect the handle keeps its wid and the
    orphaned leases appear ONLY on a negative tombstone row (never on the
    live row) — the invariant that keeps prove-liveness heartbeats from
    sheltering abandoned work."""
    mgr = _mk_socket_manager(tmp_path, 2, enable_backup_tasks=False)
    backend = mgr.backend
    try:
        wids = sorted(w for w in backend.heartbeat_view() if w >= 0)
        assert wids == [0, 1]
        assert backend.disconnect(wids[0])
        _wait_for(
            lambda: backend.stats()["leader"]["reconnects"] >= 1,
            20, "reconnect",
        )
        _wait_for(
            lambda: sorted(
                w for w, st in backend.heartbeat_view().items()
                if w >= 0 and st.alive
            ) == wids,
            20, "same ids after reconnect",
        )
        view = backend.heartbeat_view()
        for wid, st in view.items():
            if wid < 0:  # tombstone rows are dead by construction
                assert not st.alive
        pids = backend.worker_pids()
        assert len(pids) == 2 and all(isinstance(p, int) for p in pids)
    finally:
        mgr.close()
        backend.cleanup()

"""Seeded random study generator shared by the differential / property /
conformance suites (no jax, no hypothesis — plain ``random.Random``).

Workflows are multi-stage pipelines of integer-mixing tasks: each task's
output is ``(x * M + crc32(stage, task, sorted(params))) mod P`` — a
collision-sensitive pure function of ``(input, params)``, so any routing,
merging, caching or scoping bug in the engine shows up as a wrong integer,
not a tolerance miss. Bit-identical here means ``==`` on exact ints.
"""

from __future__ import annotations

import functools
import random
import time
import zlib
from typing import Any, Dict, List, Sequence, Tuple

from repro.core import StageSpec, TaskSpec, Workflow

PRIME = (1 << 61) - 1
_MULT = 1048573


def _mix_task(stage_idx: int, task_idx: int, x: int, **kw) -> int:
    tag = repr((stage_idx, task_idx, tuple(sorted(kw.items())))).encode()
    return (x * _MULT + zlib.crc32(tag)) % PRIME


def _mix_fn(stage_idx: int, task_idx: int):
    return functools.partial(_mix_task, stage_idx, task_idx)


def random_workflow(
    rng: random.Random,
    *,
    max_stages: int = 3,
    max_tasks: int = 3,
    max_card: int = 3,
    max_bytes: int = 256,
) -> Tuple[Workflow, List[str], Dict[str, int]]:
    """Random multi-stage workflow. Returns (workflow, param names in order,
    name -> cardinality). Some tasks are parameter-free (the collapsing
    normalization case); byte sizes and costs vary per task."""
    names: List[str] = []
    cards: Dict[str, int] = {}
    stages: List[StageSpec] = []
    for si in range(rng.randint(1, max_stages)):
        tasks = []
        for ti in range(rng.randint(1, max_tasks)):
            n_params = rng.choice([0, 1, 1, 2])
            task_names = []
            for _ in range(n_params):
                nm = f"p{len(names)}"
                names.append(nm)
                cards[nm] = rng.randint(1, max_card)
                task_names.append(nm)
            tasks.append(
                TaskSpec(
                    name=f"s{si}t{ti}",
                    param_names=tuple(task_names),
                    fn=_mix_fn(si, ti),
                    cost=rng.choice([0.5, 1.0, 2.0]),
                    output_bytes=rng.choice([0, max_bytes // 4, max_bytes]),
                )
            )
        stages.append(StageSpec(name=f"stage{si}", tasks=tuple(tasks)))
    return Workflow(stages=tuple(stages)), names, cards


# ---------------------------------------------------------------------------
# Spawn-picklable form: a workflow described by a plain-data LAYOUT
# ---------------------------------------------------------------------------
#
# ``_mix_task`` is module-level and task fns are ``functools.partial`` over
# it, so a layout-built workflow survives pickling — which is what lets the
# WorkerBackend conformance suite rebuild the *same* workflow inside spawn
# worker processes (``mix_study_build`` is a ProcessRpcBackend ``build``).

Layout = List[List[Tuple[str, Tuple[str, ...], float, int]]]


def workflow_from_layout(layout: Layout) -> Workflow:
    """Deterministically rebuild the workflow a layout describes; two
    processes calling this with one layout hold structurally identical
    workflows computing identical integers."""
    stages = tuple(
        StageSpec(
            name=f"stage{si}",
            tasks=tuple(
                TaskSpec(
                    name=name,
                    param_names=tuple(pnames),
                    fn=_mix_fn(si, ti),
                    cost=cost,
                    output_bytes=nbytes,
                )
                for ti, (name, pnames, cost, nbytes) in enumerate(tasks)
            ),
        )
        for si, tasks in enumerate(layout)
    )
    return Workflow(stages=stages)


def random_layout(
    rng: random.Random,
    *,
    max_stages: int = 3,
    max_tasks: int = 3,
    max_card: int = 3,
    max_bytes: int = 256,
) -> Tuple[Layout, List[str], Dict[str, int]]:
    """Random layout mirroring :func:`random_workflow`'s shape distribution
    (same task/param structure; data-only, so it crosses a spawn boundary).
    """
    names: List[str] = []
    cards: Dict[str, int] = {}
    layout: Layout = []
    for _si in range(rng.randint(1, max_stages)):
        tasks = []
        for ti in range(rng.randint(1, max_tasks)):
            n_params = rng.choice([0, 1, 1, 2])
            task_names = []
            for _ in range(n_params):
                nm = f"p{len(names)}"
                names.append(nm)
                cards[nm] = rng.randint(1, max_card)
                task_names.append(nm)
            tasks.append(
                (
                    f"s{len(layout)}t{ti}",
                    tuple(task_names),
                    rng.choice([0.5, 1.0, 2.0]),
                    rng.choice([0, max_bytes // 4, max_bytes]),
                )
            )
        layout.append(tasks)
    return layout, names, cards


def mix_study_build(layout: Layout, inputs: Sequence[Any]):
    """ProcessRpcBackend ``build``: reconstruct the layout's workflow and
    inputs inside a worker process."""
    return {"workflow": workflow_from_layout(layout), "inputs": list(inputs)}


def random_param_sets(
    rng: random.Random, names: Sequence[str], cards: Dict[str, int], n_runs: int
) -> List[Tuple[Tuple[str, int], ...]]:
    """n_runs random ParamSets (duplicates likely at small cardinality —
    exactly what exercises dedup/merging)."""
    return [
        tuple((nm, rng.randrange(cards[nm])) for nm in names) for _ in range(n_runs)
    ]


# ---------------------------------------------------------------------------
# Calibration form: tasks whose wall-time EQUALS their declared cost
# ---------------------------------------------------------------------------


def _sleep_task(stage_idx: int, duration: float, x: int, **kw) -> int:
    time.sleep(duration)
    return _mix_task(stage_idx, 0, x, **kw)


def sleep_workflow(stage_costs: Sequence[float]) -> Workflow:
    """One parametric task per stage that *sleeps* its declared cost (in
    seconds) before mixing — so a plan's ``schedule.makespan`` values are
    real wall-seconds and a measured run can be compared against
    ``simulate_stream``'s prediction (the simulator-calibration suite).
    Sleeps release the GIL, so thread-Worker concurrency is real."""
    stages = tuple(
        StageSpec(
            name=f"stage{si}",
            tasks=(
                TaskSpec(
                    name=f"s{si}t0",
                    param_names=(f"sp{si}",),
                    fn=functools.partial(_sleep_task, si, cost),
                    cost=cost,
                    output_bytes=64,
                ),
            ),
        )
        for si, cost in enumerate(stage_costs)
    )
    return Workflow(stages=stages)


def naive_outputs(workflow: Workflow, param_sets, input_state):
    """The trusted oracle: every run independently, straight-line, no reuse,
    no dispatch. Anything any executor returns must equal this exactly."""
    out = {}
    for rid, ps in enumerate(param_sets):
        d = dict(ps)
        x = input_state
        for stage in workflow.stages:
            for task in stage.tasks:
                x = task.fn(x, **{k: d[k] for k in task.param_names})
        out[rid] = x
    return out

"""Scheduler conformance battery for the hierarchical Manager (ISSUE 7,
DESIGN.md §15).

The hierarchy's contract: splitting the Manager into ``fanout`` sub-manager
pumps — with locality-aware dispatch and work stealing in any combination —
is a pure *scheduling* change. For ANY workflow, ANY parameter sets, ANY
input set and every policy × executor combination, outputs must be
**bit-identical** to the flat single-pump Manager (and therefore to the
straight-line oracle), with the accounting identity
``tasks_executed + cache_hits == plan.tasks_executed × n_inputs`` intact,
exactly one Manager session per study, and never more executed tasks than
the flat baseline. The battery also pins the spec grammar, the
scheduler-stats surface, the process-backend path, SA-index equality
through the adaptive driver, and the simulator's calibration against real
measured runs.
"""

import random
import time

import pytest

from repro.engine import ClusterSpec, execute_plan, execute_study, plan_study
from repro.engine.types import POLICIES
from repro.runtime import (
    HierarchySpec,
    Manager,
    ProcessRpcBackend,
    parse_hierarchy,
    simulate_stream,
)
from repro.runtime.hierarchy import best_affinity, path_lcp

from study_gen import (
    mix_study_build,
    naive_outputs,
    random_layout,
    random_param_sets,
    random_workflow,
    sleep_workflow,
    workflow_from_layout,
)

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# Spec grammar + prefix matching units
# ---------------------------------------------------------------------------


class TestParseHierarchy:
    def test_flat_spellings(self):
        for spec in (None, "flat", "", 1, "fanout=1"):
            assert parse_hierarchy(spec).fanout == 1, spec
        assert parse_hierarchy(None) == HierarchySpec(fanout=1)

    def test_int_and_string_fanout(self):
        assert parse_hierarchy(4).fanout == 4
        assert parse_hierarchy("fanout=4").fanout == 4
        assert parse_hierarchy("4").fanout == 4  # CLI: --hierarchy 4
        assert parse_hierarchy(" 2 ").fanout == 2
        assert parse_hierarchy(0).fanout == 1  # clamped, never zero pumps

    def test_feature_flags(self):
        spec = parse_hierarchy("fanout=4,-steal,-locality,block=16,steal_min=4")
        assert spec == HierarchySpec(
            fanout=4, steal=False, locality=False, block_size=16, steal_min=4
        )
        assert parse_hierarchy("fanout=2,+steal,+locality").steal

    def test_auto_resolves_from_pool_size(self):
        spec = parse_hierarchy("auto")
        assert spec.auto
        assert spec.resolve(4).fanout == 1  # small pools stay flat
        assert spec.resolve(32).fanout == 4
        assert spec.resolve(10_000).fanout == 16  # capped
        # resolve always clamps so every pump owns >= 1 worker
        assert parse_hierarchy(8).resolve(3).fanout == 3

    def test_passthrough_and_errors(self):
        spec = HierarchySpec(fanout=3, steal=False)
        assert parse_hierarchy(spec) is spec
        with pytest.raises(ValueError, match="unknown option"):
            parse_hierarchy("fanout=2,bogus=3")
        with pytest.raises(ValueError, match="unknown token"):
            parse_hierarchy("fanout=2,wibble")
        with pytest.raises(ValueError, match="not an int"):
            parse_hierarchy("fanout=two")
        with pytest.raises(ValueError, match="must be None"):
            parse_hierarchy(3.5)

    def test_path_lcp_and_best_affinity(self):
        assert path_lcp(("a", "b", "c"), ("a", "b", "d")) == 2
        assert path_lcp(("a",), ("b",)) == 0
        assert path_lcp(None, ("a",)) == 0
        assert path_lcp((), ()) == 0
        assert best_affinity(("a", "b"), [None, ("a",), ("a", "b")]) == 2
        assert best_affinity(None, [("a",)]) == 0


# ---------------------------------------------------------------------------
# Differential conformance: fanout matrix × policy matrix × both executors
# ---------------------------------------------------------------------------

FANOUTS = (2, 4)  # vs the implicit flat (fanout=1) baseline


def _random_case(seed: int):
    rng = random.Random(seed)
    wf, names, cards = random_workflow(rng)
    sets = random_param_sets(rng, names, cards, rng.randint(2, 16))
    inputs = [rng.randrange(1 << 40) for _ in range(rng.randint(1, 3))]
    plan_kwargs = {
        "max_bucket_size": rng.choice([1, 2, 3, None]),
        "active_paths": rng.choice([1, 2, None]),
    }
    return wf, sets, inputs, plan_kwargs


@pytest.mark.parametrize("seed", range(3))
def test_conformance_fanout_matrix(seed):
    """fanout ∈ {1, 2, 4} × all five policies × execute_study: bit-identical
    outputs, exactly one session per study, accounting identity, and never
    more executed tasks than the flat run."""
    wf, sets, inputs, plan_kwargs = _random_case(7700 + seed)
    oracles = [naive_outputs(wf, sets, x) for x in inputs]
    cluster = ClusterSpec(n_workers=4)

    for pol in POLICIES:
        plan = plan_study(wf, sets, policy=pol, **plan_kwargs)
        flat = execute_study(plan, inputs, cluster=cluster)
        for i in range(len(inputs)):
            assert flat.outputs[i] == oracles[i], (pol, i)
        assert flat.scheduler["mode"] == "flat"

        for fan in FANOUTS:
            before = Manager.sessions_started
            stream = execute_study(plan, inputs, cluster=cluster, hierarchy=fan)
            # one persistent session per study — the hierarchy adds pump
            # THREADS, not sessions
            assert Manager.sessions_started - before == 1, (pol, fan)
            assert stream.manager_sessions == 1
            for i in range(len(inputs)):
                assert stream.outputs[i] == oracles[i], (pol, fan, i)
                assert stream.outputs[i] == flat.outputs[i], (pol, fan, i)
            # exactly-once accounting survives re-queueing across sub-pumps
            assert (
                stream.tasks_executed + stream.cache_hits
                == plan.tasks_executed * len(inputs)
            ), (pol, fan)
            # scheduling never ADDS work: reuse at least matches flat
            assert stream.tasks_executed <= flat.tasks_executed + flat.cache_hits
            assert stream.scheduler["fanout"] == min(fan, cluster.n_workers)


@pytest.mark.parametrize("hierarchy", ["auto", "fanout=2,-steal",
                                       "fanout=2,-locality",
                                       "fanout=4,-steal,-locality,block=1"])
def test_conformance_feature_flag_variants(hierarchy):
    """Every feature subset (no-steal, no-locality, degenerate block) is
    still bit-identical — the flags trade performance, never results."""
    wf, sets, inputs, plan_kwargs = _random_case(8800)
    oracles = [naive_outputs(wf, sets, x) for x in inputs]
    plan = plan_study(wf, sets, policy="hybrid", **plan_kwargs)
    stream = execute_study(
        plan, inputs, cluster=ClusterSpec(n_workers=4), hierarchy=hierarchy
    )
    for i in range(len(inputs)):
        assert stream.outputs[i] == oracles[i], i
    assert (
        stream.tasks_executed + stream.cache_hits
        == plan.tasks_executed * len(inputs)
    )


@pytest.mark.parametrize("policy", POLICIES)
def test_execute_plan_hierarchy_matches_flat(policy):
    """The single-input executor threads hierarchy= through to the same
    Manager — same outputs, same accounting, policy by policy."""
    wf, sets, inputs, plan_kwargs = _random_case(9900)
    oracle = naive_outputs(wf, sets, inputs[0])
    plan = plan_study(wf, sets, policy=policy, **plan_kwargs)
    cluster = ClusterSpec(n_workers=4)
    flat = execute_plan(plan, inputs[0], cluster=cluster)
    hier = execute_plan(plan, inputs[0], cluster=cluster, hierarchy=2)
    assert flat.outputs == oracle
    assert hier.outputs == oracle
    assert (
        hier.tasks_executed + hier.cache_hits
        == flat.tasks_executed + flat.cache_hits
    )


def test_external_manager_rejects_hierarchy_kwarg():
    """An external Manager session already carries its own topology;
    silently ignoring a conflicting hierarchy= would be a foot-gun."""
    wf, sets, inputs, plan_kwargs = _random_case(4242)
    plan = plan_study(wf, sets, policy="hybrid", **plan_kwargs)
    mgr = Manager(hierarchy=2)
    mgr.start(2)
    try:
        stream = execute_study(plan, inputs, manager=mgr)  # inherits fanout=2
        assert stream.outputs[0] == naive_outputs(wf, sets, inputs[0])
        with pytest.raises(ValueError, match="hierarchy"):
            execute_study(plan, inputs, manager=mgr, hierarchy=2)
    finally:
        mgr.close()


def test_scheduler_stats_surface():
    """The stats snapshot is coherent: hierarchical mode with the resolved
    fanout, every sub-pump accounted, counters non-negative, and the wall
    clock real. Locality/steal activity is workload-dependent, so only
    structure is pinned here (activity is pinned by the storm tests)."""
    rng = random.Random(606)
    wf, names, cards = random_workflow(rng, max_stages=3)
    sets = random_param_sets(rng, names, cards, 24)
    inputs = [rng.randrange(1 << 40) for _ in range(4)]
    plan = plan_study(wf, sets, policy="hybrid", max_bucket_size=1)
    stream = execute_study(
        plan, inputs, cluster=ClusterSpec(n_workers=4), hierarchy=4
    )
    sched = stream.scheduler
    assert sched["mode"] == "hierarchical"
    assert sched["fanout"] == 4
    assert len(sched["sub_occupancy"]) == 4
    assert len(sched["dispatched_per_sub"]) == 4
    # every settled bucket was dispatched by SOME sub-pump (retries/backups
    # may add more dispatches, never fewer)
    assert sum(sched["dispatched_per_sub"]) >= plan.bucket_count()
    assert sched["steals"] >= 0 and sched["steal_items"] >= sched["steals"] * 0
    assert 0.0 <= sched["locality_hit_rate"] <= 1.0
    assert sched["wall_seconds"] > 0
    assert 0.0 <= sched["pump_occupancy"]
    assert len(sched["worker_busy_seconds"]) == 4
    assert sched["worker_idle_fraction"] <= 1.0
    # flat runs advertise the flat shape
    flat = execute_study(plan, inputs, cluster=ClusterSpec(n_workers=2))
    assert flat.scheduler["mode"] == "flat"
    assert flat.scheduler["fanout"] == 1
    assert flat.scheduler["sub_occupancy"] == []


# ---------------------------------------------------------------------------
# Process backend: the hierarchy dispatches through RPC worker processes
# ---------------------------------------------------------------------------


def test_hierarchy_bit_identical_on_process_backend(tmp_path):
    """fanout=2 over RPC worker processes: sub-pumps partition the worker
    pool and drive targeted offer_batch calls concurrently; results must
    still equal the oracle and the flat thread run exactly."""
    rng = random.Random(1177)
    layout, names, cards = random_layout(rng, max_stages=2)
    wf = workflow_from_layout(layout)
    sets = random_param_sets(rng, names, cards, 10)
    inputs = [5, 13]
    oracles = [naive_outputs(wf, sets, x) for x in inputs]

    mgr = Manager(
        backend=ProcessRpcBackend(
            build=mix_study_build,
            build_kwargs={"layout": layout, "inputs": inputs},
            store_dir=str(tmp_path / "store"),
            heartbeat_interval=0.05,
        ),
        enable_backup_tasks=False,
        hierarchy=2,
    )
    mgr.start(2)
    try:
        for policy in ("stage", "hybrid"):
            plan = plan_study(wf, sets, policy=policy, max_bucket_size=2)
            stream = execute_study(
                plan, inputs, manager=mgr, key_prefix=f"{policy}:"
            )
            assert stream.backend == "process"
            for i in range(len(inputs)):
                assert stream.outputs[i] == oracles[i], (policy, i)
        assert mgr.scheduler_stats()["mode"] == "hierarchical"
    finally:
        mgr.close()


# ---------------------------------------------------------------------------
# SA indices through the adaptive driver: hierarchy changes nothing
# ---------------------------------------------------------------------------


def _objective(leaf, _i):
    return float(leaf % 9973) / 9973.0


def test_sa_indices_bit_identical_flat_vs_hierarchical():
    from repro.core import ParamSpace
    from repro.study import StudyDriver

    layout = [
        [("s0t0", (), 1.0, 64)],
        [
            ("s1t0", ("p0",), 1.0, 64),
            ("s1t1", ("p1",), 1.0, 64),
            ("s1t2", ("p2",), 1.0, 64),
        ],
    ]
    space = ParamSpace.from_dict({f"p{i}": [0, 1, 2] for i in range(3)})
    inputs = [417]

    def run(hierarchy):
        driver = StudyDriver(
            workflow_from_layout(layout),
            space,
            inputs,
            objective=_objective,
            seed=5,
            engine_policy="hybrid",
            cluster=ClusterSpec(n_workers=4),
            n_boot=8,
            hierarchy=hierarchy,
        )
        try:
            return driver.run(max_rounds=2)
        finally:
            driver.close()

    flat_state = run(None)
    hier_state = run("fanout=4,block=1")
    assert hier_state.evaluated == flat_state.evaluated
    assert len(hier_state.rounds) == len(flat_state.rounds) == 2
    for hr, fr in zip(hier_state.rounds, flat_state.rounds):
        assert hr.outputs == fr.outputs
        assert hr.analysis == fr.analysis  # indices + CIs, exact floats
        assert hr.decision == fr.decision
    assert hier_state.active == flat_state.active


# ---------------------------------------------------------------------------
# Hypothesis layer: shrinkable conformance over the same contract
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    class TestHypothesisHierarchyConformance:
        @given(
            seed=st.integers(min_value=0, max_value=2**20),
            n_runs=st.integers(min_value=1, max_value=12),
            fanout=st.sampled_from([2, 3, 4]),
            block=st.sampled_from([1, 2, 8]),
        )
        @settings(max_examples=10, deadline=None)
        def test_hierarchy_bit_identical(self, seed, n_runs, fanout, block):
            rng = random.Random(seed)
            wf, names, cards = random_workflow(rng)
            sets = random_param_sets(rng, names, cards, n_runs)
            inputs = [rng.randrange(1 << 40) for _ in range(rng.randint(1, 2))]
            oracles = [naive_outputs(wf, sets, x) for x in inputs]
            plan = plan_study(
                wf, sets, policy=rng.choice(list(POLICIES)),
                max_bucket_size=rng.choice([1, 2, None]),
            )
            stream = execute_study(
                plan,
                inputs,
                cluster=ClusterSpec(n_workers=4),
                hierarchy=HierarchySpec(fanout=fanout, block_size=block),
            )
            for i in range(len(inputs)):
                assert stream.outputs[i] == oracles[i], i
            assert (
                stream.tasks_executed + stream.cache_hits
                == plan.tasks_executed * len(inputs)
            )


# ---------------------------------------------------------------------------
# Simulator calibration: simulate_stream vs MEASURED ThreadBackend runs
# ---------------------------------------------------------------------------


def _calibration_case():
    """A sleep workflow whose declared costs ARE wall-seconds, planned so
    the per-stage bucket makespans feed simulate_stream directly."""
    wf = sleep_workflow([0.02, 0.03])
    sets = [((f"sp0", i % 4), (f"sp1", i % 3)) for i in range(8)]
    plan = plan_study(wf, sets, policy="stage", max_bucket_size=2)
    costs = [
        [b.schedule.makespan for b in stage.buckets] for stage in plan.stages
    ]
    return wf, sets, plan, costs


class TestSimulatorCalibration:
    """``simulate_stream`` is the autotuner's oracle, so its predictions
    must track reality. Tolerance (stated): a measured ThreadBackend run
    must land in ``[0.85 × predicted, 1.6 × predicted + 0.5 s]`` — the
    lower bound catches a simulator that over-charges (sleeps are real
    lower bounds on wall time), the upper bound catches one that ignores
    scheduling costs, with generous slack for loaded CI machines."""

    TOL_LOW = 0.85
    TOL_HIGH = 1.6
    TOL_SLACK = 0.5

    def _measure(self, wf, sets, plan, *, workers, hierarchy):
        t0 = time.perf_counter()
        stream = execute_study(
            plan,
            [101, 202],
            cluster=ClusterSpec(n_workers=workers, enable_backup_tasks=False),
            hierarchy=hierarchy,
        )
        measured = time.perf_counter() - t0
        assert stream.outputs[0] == naive_outputs(wf, sets, 101)
        return measured

    def _predict(self, costs, *, workers, fanout):
        sim = simulate_stream(
            costs,
            2,
            n_nodes=1,
            cores_per_node=workers,
            dispatch_latency=0.0,
            io_per_bucket=0.0,
            node_speed_sigma=0.0,
            input_cost_sigma=0.0,
            fanout=fanout,
        )
        return sim.makespan

    @pytest.mark.parametrize("fanout", [1, 2])
    def test_predicted_wall_time_tracks_measured(self, fanout):
        wf, sets, plan, costs = _calibration_case()
        predicted = self._predict(costs, workers=4, fanout=fanout)
        assert predicted > 0.05  # a real workload, not a degenerate case
        measured = self._measure(wf, sets, plan, workers=4, hierarchy=fanout)
        assert measured >= self.TOL_LOW * predicted, (measured, predicted)
        assert measured <= self.TOL_HIGH * predicted + self.TOL_SLACK, (
            measured,
            predicted,
        )

    def test_fewer_workers_predictably_slower(self):
        """Calibration is relative too: the simulator's 1-worker/4-worker
        makespan ratio must match the measured ratio's direction."""
        wf, sets, plan, costs = _calibration_case()
        p1 = self._predict(costs, workers=1, fanout=1)
        p4 = self._predict(costs, workers=4, fanout=1)
        assert p1 > p4 * 1.5  # the model scales with workers
        m1 = self._measure(wf, sets, plan, workers=1, hierarchy=None)
        m4 = self._measure(wf, sets, plan, workers=4, hierarchy=None)
        assert m1 > m4, (m1, m4)

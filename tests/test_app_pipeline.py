"""Integration tests: the pathology workflow + SA study driver.

The critical invariant (paper §II-B): computation reuse is an optimization,
never an approximation — every strategy must produce identical Dice vectors.
"""

import numpy as np
import pytest

from repro.app import TABLE1_SPACE, run_study, synthetic_tile
from repro.app import ops
from repro.app.pipeline import build_workflow
from repro.core import halton_sequence, moat_indices, morris_trajectories
from repro.core.params import ParamSpace
from repro.engine import ClusterSpec, execute_plan, plan_study

import jax.numpy as jnp

H = W = 64


@pytest.fixture(scope="module")
def tile():
    return synthetic_tile(H, W, seed=3)


SMALL_SPACE = ParamSpace.from_dict(
    {
        "B": [210, 230],
        "G": [210, 230],
        "R": [210, 230],
        "T1": [2.5, 5.0],
        "T2": [2.5, 5.0],
        "G1": [20, 40],
        "G2": [10, 20],
        "minS": [2, 10],
        "maxS": [900, 1200],
        "minSPL": [5, 20],
        "minSS": [2, 10],
        "maxSS": [900, 1200],
        "FH": [4, 8],
        "RC": [4, 8],
        "WConn": [4, 8],
    }
)


@pytest.fixture(scope="module")
def param_sets():
    pts = halton_sequence(12, SMALL_SPACE.dim)
    return SMALL_SPACE.quantise(pts)


class TestOps:
    def test_background_mask(self, tile):
        fg = ops.background_mask(jnp.asarray(tile), 230.0, 230.0, 230.0)
        # glass band at the top must be background
        assert float(fg[: H // 8].mean()) < 0.2
        assert float(fg[H // 2 :].mean()) > 0.8

    def test_area_filter_removes_small(self):
        m = jnp.zeros((32, 32), bool).at[2:4, 2:4].set(True).at[10:20, 10:20].set(True)
        out = ops.area_filter(m, 10, 1000)
        assert not bool(out[2, 2]) and bool(out[15, 15])

    def test_fill_holes(self):
        m = jnp.zeros((16, 16), bool).at[4:12, 4:12].set(True).at[7:9, 7:9].set(False)
        out = ops.fill_holes(m, conn=4)
        assert bool(out[7, 7]) and not bool(out[0, 0])

    def test_label_components_two_blobs(self):
        m = jnp.zeros((16, 16), bool).at[2:5, 2:5].set(True).at[10:13, 10:13].set(True)
        lab = ops.label_components(m, conn=8)
        l1, l2 = int(lab[3, 3]), int(lab[11, 11])
        assert l1 != l2 and l1 >= 0 and l2 >= 0
        assert int(lab[0, 0]) == -1
        sizes = ops.component_sizes(lab)
        assert int(sizes[3, 3]) == 9 and int(sizes[0, 0]) == 0

    def test_watershed_splits_touching_blobs(self):
        m = np.zeros((24, 40), bool)
        yy, xx = np.mgrid[0:24, 0:40]
        m |= (yy - 12) ** 2 + (xx - 13) ** 2 < 64
        m |= (yy - 12) ** 2 + (xx - 27) ** 2 < 64
        out = ops.watershed_split(jnp.asarray(m), 5, conn=8)
        lab = ops.label_components(out, conn=8)
        n_comp = len({int(v) for v in np.unique(np.asarray(lab)) if v >= 0})
        assert n_comp >= 2  # split line separates the two discs


class TestStudy:
    def test_strategies_agree_exactly(self, tile, param_sets):
        base = run_study(tile, param_sets, strategy="none")
        for strat, kw in [
            ("stage", {}),
            ("rtma", {"max_bucket_size": 4}),
            ("rmsr", {"active_paths": 2}),
        ]:
            out = run_study(tile, param_sets, strategy=strat, **kw)
            np.testing.assert_allclose(out["dice"], base["dice"], atol=0, rtol=0)

    def test_reuse_reduces_task_count(self, tile, param_sets):
        none = run_study(tile, param_sets, strategy="none")
        stage = run_study(tile, param_sets, strategy="stage")
        rmsr = run_study(tile, param_sets, strategy="rmsr")
        assert none["tasks_executed"] == none["tasks_total"]
        assert stage["tasks_executed"] <= none["tasks_executed"]
        assert rmsr["tasks_executed"] <= stage["tasks_executed"]
        assert rmsr["reuse_fraction"] > 0.0

    def test_dice_in_range_and_default_is_one(self, tile):
        ref = TABLE1_SPACE.default()
        out = run_study(tile, [ref], strategy="none")
        assert out["dice"][0] == pytest.approx(1.0)

    def test_engine_acceptance_64_sets(self, tile):
        """ISSUE acceptance: for ≥64 param sets, hybrid's planned peak_bytes
        ≤ rtma's at equal bucket size, hybrid's tasks_executed ≤ the
        per-bucket RTMA count, and execute_plan outputs are bit-identical
        across the three policies and across n_workers ∈ {1, 4}."""
        h, w = tile.shape[:2]
        wf = build_workflow(h, w)
        pts = halton_sequence(64, SMALL_SPACE.dim)
        sets = SMALL_SPACE.quantise(pts)
        plans = {
            pol: plan_study(wf, sets, policy=pol, max_bucket_size=8, active_paths=2)
            for pol in ("rtma", "rmsr", "hybrid")
        }
        assert plans["hybrid"].peak_bytes <= plans["rtma"].peak_bytes
        assert plans["hybrid"].tasks_executed <= plans["rtma"].tasks_executed

        raw = {"raw": jnp.asarray(tile)}
        masks = {}
        for pol, plan in plans.items():
            for workers in (1, 4):
                res = execute_plan(plan, raw, cluster=ClusterSpec(n_workers=workers))
                masks[(pol, workers)] = {
                    rid: np.asarray(out["mask"]) for rid, out in res.outputs.items()
                }
        base = masks[("rtma", 1)]
        assert set(base) == set(range(64))
        for key, got in masks.items():
            for rid in range(64):
                np.testing.assert_array_equal(got[rid], base[rid], err_msg=str((key, rid)))

    def test_moat_end_to_end(self, tile):
        """MOAT screening over a reduced space; reuse must be high because
        consecutive MOAT runs differ in a single parameter."""
        small = SMALL_SPACE
        sets, moves = morris_trajectories(small, 2, seed=1)
        out = run_study(tile, sets, strategy="rmsr")
        res = moat_indices(small, out["dice"], moves)
        assert set(res.mu_star) == set(small.names)
        assert all(v >= 0 for v in res.mu_star.values())
        assert out["reuse_fraction"] > 0.3  # MOAT shares long prefixes

"""Empirical check of the §2 AOT liveness proof (DESIGN.md §2).

A plan's ``schedule.peak_bytes`` is advertised as a *proof* about any
executor that replays the frozen order with the liveness rule (a node's
buffer becomes live when it executes, a parent dies with its last executed
child, leaves are emitted immediately). This suite replays random
rmsr/rtma/hybrid schedules while instrumenting exactly that rule and
asserts the observed live-byte high-water mark never exceeds the proven
``peak_bytes`` — the AOT bound, checked against an actual execution trace.
"""

import random

import pytest

from repro.core.rmsr import replay_schedule
from repro.engine import plan_study

from study_gen import random_param_sets, random_workflow


def replay_with_live_bytes(bucket, input_state):
    """Replay the bucket's frozen schedule while tracking live bytes under
    the executor's own liveness rule; returns (outputs, observed peak)."""
    tree, order = bucket.tree, bucket.schedule.order
    live = {}
    remaining = {}
    cur = peak = 0
    trace = []

    for node in order:
        task = tree.stage.tasks[node.depth]
        nbytes = task.bound_bytes(dict(node.instances[0].params))
        live[node.uid] = nbytes
        cur += nbytes
        peak = max(peak, cur)
        trace.append(cur)
        if node.is_leaf:
            cur -= live.pop(node.uid)  # emitted immediately
        else:
            remaining[node.uid] = len(node.children)
        parent = node.parent
        if parent is not None and parent.depth >= 0:
            remaining[parent.uid] -= 1
            if remaining[parent.uid] == 0:
                cur -= live.pop(parent.uid)  # parent dies with last child

    assert cur == 0, "liveness leak: buffers still live after replay"
    outputs, _, _ = replay_schedule(tree, order, input_state)
    return outputs, peak


@pytest.mark.parametrize("seed", range(12))
def test_observed_live_bytes_never_exceed_proof(seed):
    rng = random.Random(4200 + seed)
    wf, names, cards = random_workflow(rng, max_bytes=512)
    sets = random_param_sets(rng, names, cards, rng.randint(2, 28))
    checked = 0
    for pol in ("rtma", "rmsr", "hybrid"):
        plan = plan_study(
            wf,
            sets,
            policy=pol,
            max_bucket_size=rng.choice([2, 3, None]),
            active_paths=rng.choice([1, 2, 3, None]),
        )
        for stage_plan in plan.stages:
            for bucket in stage_plan.buckets:
                _, observed = replay_with_live_bytes(bucket, 7)
                assert observed <= bucket.schedule.peak_bytes, (
                    pol,
                    stage_plan.stage.name,
                    observed,
                    bucket.schedule.peak_bytes,
                )
                checked += 1
    assert checked > 0


def test_instrumentation_is_not_vacuous():
    """The tracker must actually observe nonzero live bytes on a workflow
    with nonzero buffers (guards against a trivially-passing instrument)."""
    rng = random.Random(1)
    while True:
        wf, names, cards = random_workflow(rng, max_bytes=512)
        if any(t.output_bytes for s in wf.stages for t in s.tasks):
            break
    sets = random_param_sets(rng, names, cards, 8)
    plan = plan_study(wf, sets, policy="rmsr", active_paths=2)
    peaks = [
        replay_with_live_bytes(b, 3)[1]
        for sp in plan.stages
        for b in sp.buckets
    ]
    assert any(p > 0 for p in peaks)


def test_plan_peak_respects_memory_budget_end_to_end():
    """Budget-solved plans: the observed live peak of every bucket must fit
    the schedule budget the planner solved against."""
    from repro.engine import MemoryBudget

    rng = random.Random(99)
    wf, names, cards = random_workflow(rng, max_bytes=512)
    sets = random_param_sets(rng, names, cards, 24)
    budget = MemoryBudget(bytes=8 * 512)
    for pol in ("rtma", "rmsr", "hybrid"):
        plan = plan_study(wf, sets, policy=pol, memory=budget)
        for sp in plan.stages:
            for bucket in sp.buckets:
                _, observed = replay_with_live_bytes(bucket, 11)
                assert observed <= budget.schedule_bytes, (pol, sp.stage.name)

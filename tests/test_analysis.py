"""Self-tests for the static-analysis suite (``repro.analysis``).

Each historical bug class this repo has actually shipped (and fixed) gets a
minimal fixture that MUST keep firing the pass that would have caught it:

* the dequeue/lease race (unlocked queue write)        -> locks L201
* the stale-memo resubmission (TOCTOU read)            -> locks L202
* manifest I/O under the store lock                    -> blocking B401/B402
* the stranded-item shard-death livelock (sleep held)  -> blocking B401
* the torn manifest tail / frame schema drift          -> frames W503

plus clean-code negatives so the passes don't rot into noise, and an
integration test that holds ``src/`` itself at zero unsuppressed findings.
"""

import json
import pathlib
import subprocess
import sys

import pytest

from repro.analysis import (
    Baseline,
    run_paths,
    run_sources,
    source_from_text,
)
from repro.analysis.lockmodel import collect_module
from repro.analysis.runner import default_baseline_path, default_target

REPO = pathlib.Path(__file__).resolve().parents[1]


def analyze(*texts):
    return run_sources([source_from_text(t, f"fix{i}.py") for i, t in enumerate(texts)])


def codes(report):
    return sorted(f.code for f in report.findings)


# ---------------------------------------------------------------------------
# Pass 1 — lock discipline
# ---------------------------------------------------------------------------

DEQUEUE_RACE = """
import threading

class Manager:
    def __init__(self):
        self._lock = threading.Lock()
        self._queue = []  # guard: _lock

    def enqueue(self, item):
        with self._lock:
            self._queue.append(item)

    def dequeue(self):
        if self._queue:
            return self._queue.pop()
        return None
"""


def test_dequeue_race_fires_declared_mode():
    """The shipped bug: dequeue raced enqueue because the pop ran outside
    the lock — an item could be leased twice. Declared mode flags both the
    unlocked read and the unlocked write."""
    report = analyze(DEQUEUE_RACE)
    assert "L201" in codes(report)  # the .pop() write
    assert "L202" in codes(report)  # the truthiness read
    assert all(f.path == "fix0.py" for f in report.findings)


STALE_MEMO = """
import threading

class Memo:
    def __init__(self):
        self._lock = threading.Lock()
        self._done = {}  # guard: _lock

    def mark(self, key, value):
        with self._lock:
            self._done[key] = value

    def maybe_submit(self, key, submit):
        if key in self._done:
            return
        submit(key)
"""


def test_stale_memo_toctou_read_fires():
    """The shipped bug: a membership probe outside the lock let two pumps
    both miss and resubmit the same key."""
    report = analyze(STALE_MEMO)
    assert codes(report) == ["L202"]
    (f,) = report.findings
    assert "maybe_submit" in f.message


INFERENCE_RACE = """
import threading

class Counted:
    def __init__(self):
        self._lock = threading.Lock()
        self._n = 0

    def a(self):
        with self._lock:
            self._n += 1

    def b(self):
        with self._lock:
            self._n += 1

    def c(self):
        with self._lock:
            return self._n

    def d(self):
        with self._lock:
            return self._n

    def racy(self):
        return self._n
"""


def test_inference_mode_flags_minority_unlocked_access():
    """With no guard declarations, dominant with-lock usage (>=4 sites,
    >=75%, at least one held write) infers the guard and flags the outlier."""
    report = analyze(INFERENCE_RACE)
    assert codes(report) == ["L212"]
    (f,) = report.findings
    assert "racy" in f.message and "inferred" in f.message


def test_declared_mode_disables_inference():
    """One guard declaration switches the class to declared mode: an
    attribute with dominant-lock usage but NO declaration is not checked."""
    text = INFERENCE_RACE.replace(
        "self._n = 0", "self._n = 0\n        self._other = []  # guard: _lock"
    )
    report = analyze(text)
    assert codes(report) == []  # _n undeclared -> ignored in declared mode


def test_locked_suffix_and_holds_annotation_are_honoured():
    report = analyze(
        """
import threading

class S:
    def __init__(self):
        self._lock = threading.Lock()
        self._v = 0  # guard: _lock

    def bump_locked(self):
        self._v += 1

    def peek(self):  # holds: _lock
        return self._v
"""
    )
    assert codes(report) == []


def test_inline_suppression_waives_finding():
    text = STALE_MEMO.replace(
        "        if key in self._done:",
        "        # analysis: ok[locks] probe is advisory; submit() dedupes\n"
        "        if key in self._done:",
    )
    report = analyze(text)
    assert codes(report) == []
    assert report.suppressed == 1


def test_condition_variable_aliases_to_underlying_lock():
    report = analyze(
        """
import threading

class W:
    def __init__(self):
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._items = []  # guard: _lock

    def put(self, x):
        with self._cond:
            self._items.append(x)
"""
    )
    assert codes(report) == []


# ---------------------------------------------------------------------------
# Pass 1b — lock-ordering cycles
# ---------------------------------------------------------------------------

ORDER_CYCLE = """
import threading

class A:
    def __init__(self):
        self._lock = threading.Lock()
        self.b = B()

    def go(self):
        with self._lock:
            self.b.poke()

class B:
    def __init__(self):
        self._lock = threading.Lock()
        self.a = A()

    def poke(self):
        with self._lock:
            self.a.go()
"""


def test_lock_ordering_cycle_detected():
    report = analyze(ORDER_CYCLE)
    assert "O301" in codes(report)
    (f,) = [f for f in report.findings if f.code == "O301"]
    assert "A._lock" in f.message and "B._lock" in f.message


def test_consistent_ordering_has_no_cycle():
    report = analyze(ORDER_CYCLE.replace(
        "    def poke(self):\n        with self._lock:\n            self.a.go()",
        "    def poke(self):\n        with self._lock:\n            pass",
    ))
    assert "O301" not in codes(report)


# ---------------------------------------------------------------------------
# Pass 2 — blocking calls under a held lock
# ---------------------------------------------------------------------------

IO_UNDER_LOCK = """
import threading

class ManifestWriter:
    def __init__(self):
        self._lock = threading.Lock()

    def append(self, path, row):
        with self._lock:
            path.write_bytes(row)
"""


def test_manifest_io_under_lock_fires():
    """The shipped bug: manifest appends ran inside the store lock, so one
    slow fsync stalled every reader."""
    report = analyze(IO_UNDER_LOCK)
    assert codes(report) == ["B401"]


def test_io_one_call_level_deep_fires():
    report = analyze(
        """
import os
import threading

class Store:
    def __init__(self):
        self._lock = threading.Lock()

    def save(self):
        with self._lock:
            self._spill()

    def _spill(self):
        os.replace("a", "b")
"""
    )
    assert codes(report) == ["B402"]


def test_shard_death_livelock_sleep_under_lock_fires():
    """The shipped bug: the pump slept waiting for a dead shard's workers
    while holding the scheduler lock — heartbeat expiry needed that lock to
    re-enqueue the shard's stranded items, so the fleet livelocked."""
    report = analyze(
        """
import time
import threading

class Pump:
    def __init__(self):
        self._lock = threading.Lock()

    def wait_for_shard(self, shard):
        with self._lock:
            while not shard.drained():
                time.sleep(0.05)
"""
    )
    assert codes(report) == ["B401"]
    assert "time.sleep" in report.findings[0].message


def test_io_outside_lock_is_clean():
    report = analyze(
        """
import threading

class Store:
    def __init__(self):
        self._lock = threading.Lock()
        self._ram = {}  # guard: _lock

    def commit(self, path, key, row):
        with self._lock:
            self._ram[key] = row
        path.write_bytes(row)
"""
    )
    assert codes(report) == []


# ---------------------------------------------------------------------------
# Pass 3 — wire-frame conformance
# ---------------------------------------------------------------------------

TORN_TAIL = """
def write_manifest(conn, lock, rows):
    _send_frame(conn, lock, {"t": "manifest", "rows": rows})

def read_manifest(conn):
    msg = _recv_frame(conn)
    k = msg.get("t")
    if k == "manifest":
        rows = msg["rows"]
        crc = msg["tail_crc"]
        return rows, crc
"""


def test_frame_schema_drift_fires():
    """The shipped bug class: a consumer grew a required field the producer
    never sent — on the wire that read as a torn/short record."""
    report = analyze(TORN_TAIL)
    assert codes(report) == ["W503"]
    assert "tail_crc" in report.findings[0].message


def test_frame_tag_mismatches_fire_both_directions():
    report = analyze(
        """
def send(conn, lock):
    _send_frame(conn, lock, {"t": "orphaned", "n": 1})

def recv(conn):
    msg = _recv_frame(conn)
    k = msg.get("t")
    if k == "unknown":
        return msg["n"]
"""
    )
    assert codes(report) == ["W501", "W502"]


def test_frames_match_across_files_and_annotations():
    """Producers and consumers live in different modules (leader vs worker
    file), and NotEq-style handshakes are covered by the annotation form."""
    producer = """
def hello(conn, lock):
    _send_frame(conn, lock, {"t": "welcome", "wid": 3})
"""
    consumer = """
def dial(conn):
    # frame-consumer: welcome via reply
    reply = _recv_frame(conn)
    if reply.get("t") != "welcome":
        return None
    return reply["wid"]
"""
    report = analyze(producer, consumer)
    assert codes(report) == []


def test_frame_splat_producer_resolves_base_dict():
    report = analyze(
        """
def announce(conn, lock, study):
    base = {"t": "study", "round": 1}
    _send_frame(conn, lock, {**base, "extra": study})

def on_study(conn):
    msg = _recv_frame(conn)
    k = msg.get("t")
    if k == "study":
        return msg["round"], msg["missing_field"]
"""
    )
    assert codes(report) == ["W503"]


# ---------------------------------------------------------------------------
# Pass 4 — spawn picklability & determinism
# ---------------------------------------------------------------------------

def test_lambda_into_spawn_boundary_fires():
    report = analyze(
        """
def launch(backend_cls):
    return backend_cls(build=lambda: {"model": 1})
"""
    )
    assert codes(report) == ["S601"]


def test_closure_fn_into_pool_initializer_fires():
    report = analyze(
        """
def launch(Pool):
    def init_worker():
        pass
    return Pool(4, initializer=init_worker)
"""
    )
    assert codes(report) == ["S602"]


def test_lambda_default_on_spawn_param_fires():
    report = analyze(
        """
def start(n, build=lambda: {}):
    return n
"""
    )
    assert codes(report) == ["S603"]


def test_module_level_fn_into_process_is_clean():
    report = analyze(
        """
def worker_main(q):
    q.put(1)

def launch(Process, q):
    return Process(target=worker_main, args=(q,))
"""
    )
    assert codes(report) == []


def test_wall_clock_in_key_derivation_fires():
    report = analyze(
        """
import time

def result_key(run):
    return f"{run}-{time.time()}"
"""
    )
    assert codes(report) == ["S611"]


def test_dict_order_in_recipe_fires_and_sorted_is_clean():
    racy = analyze(
        """
def recipe_key(params):
    return tuple(params.items())
"""
    )
    assert codes(racy) == ["S612"]
    clean = analyze(
        """
def recipe_key(params):
    return tuple(sorted(params.items()))
"""
    )
    assert codes(clean) == []


def test_json_dumps_without_sort_keys_fires():
    report = analyze(
        """
import json

def params_key(params):
    return json.dumps(params, sort_keys=True) + json.dumps(params)
"""
    )
    assert codes(report) == ["S613"]


# ---------------------------------------------------------------------------
# Baseline mechanics
# ---------------------------------------------------------------------------

def test_baseline_splits_known_and_stale(tmp_path):
    report = analyze(STALE_MEMO)
    (f,) = report.findings
    baseline = Baseline({f.fingerprint: "legacy, tracked in #12", "locks:gone.py:L201:x": "fixed long ago"})
    report2 = run_sources([source_from_text(STALE_MEMO, "fix0.py")], baseline)
    assert report2.ok  # known finding is baselined out
    assert [k.fingerprint for k in report2.baselined] == [f.fingerprint]
    assert report2.stale == ["locks:gone.py:L201:x"]
    assert not report2.strict_ok  # stale entries fail strict


def test_baseline_loader_rejects_unexplained_entries(tmp_path):
    p = tmp_path / "baseline.json"
    p.write_text(json.dumps({"entries": [{"fingerprint": "locks:a.py:L201:x", "reason": ""}]}))
    with pytest.raises(ValueError, match="unexplained"):
        Baseline.load(p)


def test_fingerprints_survive_line_drift():
    shifted = "\n\n\n" + STALE_MEMO
    a = analyze(STALE_MEMO).findings[0]
    b = run_sources([source_from_text(shifted, "fix0.py")]).findings[0]
    assert a.fingerprint == b.fingerprint
    assert a.line != b.line


# ---------------------------------------------------------------------------
# Integration: the tree itself
# ---------------------------------------------------------------------------

def test_src_tree_is_clean_under_strict():
    """The gate this PR establishes: zero unsuppressed findings over
    ``src/repro`` against the checked-in baseline, and no stale entries."""
    report = run_paths()
    assert report.strict_ok, "\n" + report.render()


def test_shipped_baseline_is_empty_of_entries():
    """Real findings were fixed, deliberate design points are suppressed
    inline with reasons — the baseline ships with no entries at all."""
    assert Baseline.load(default_baseline_path()).entries == {}


@pytest.mark.parametrize(
    "rel",
    [
        "runtime/manager.py",
        "runtime/transport.py",
        "runtime/net.py",
        "runtime/storage.py",
        "runtime/objstore.py",
    ],
)
def test_hot_modules_run_in_declared_mode(rel):
    """Regression guard for the annotation satellite: every lock-owning
    class in the hot runtime modules declares its guards, so the precise
    declared-mode checks (not the heuristic inference) are what gate them."""
    path = default_target() / rel
    from repro.analysis.core import load_source

    mod = collect_module(load_source(path, None))
    # classes whose only locks are frame-SEND serialization locks guard a
    # wire, not state — declared mode is about state guards
    lock_owning = [
        c for c in mod.classes.values()
        if any("send" not in name for name in c.locks)
    ]
    assert lock_owning, f"no lock-owning classes found in {rel}?"
    undeclared = [c.name for c in lock_owning if not c.declared]
    assert not undeclared, (
        f"{rel}: classes in inference mode (declare their guards): {undeclared}"
    )


def test_cli_strict_gate_exits_zero():
    """`python -m repro.analysis --strict` is the CI gate — it must exit 0
    on the shipped tree."""
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--strict"],
        cwd=REPO,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"},
        capture_output=True,
        text=True,
        timeout=120,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr

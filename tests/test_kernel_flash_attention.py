"""FlashAttention-2 Pallas kernel vs the dense jnp oracle."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need hypothesis; skip cleanly without it
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ref import attention_ref
from repro.models.attention import blocked_attention


def qkv(b, sq, sk, h, kv, d, seed, dtype=np.float32):
    rng = np.random.default_rng(seed)
    q = rng.normal(0, 1, (b, sq, h, d)).astype(dtype)
    k = rng.normal(0, 1, (b, sk, kv, d)).astype(dtype)
    v = rng.normal(0, 1, (b, sk, kv, d)).astype(dtype)
    return map(jnp.asarray, (q, k, v))


@pytest.mark.parametrize(
    "b,s,h,kv,d,bq,bk",
    [
        (1, 64, 2, 2, 32, 16, 16),
        (2, 128, 4, 2, 32, 32, 64),   # GQA 2:1
        (1, 96, 4, 1, 16, 32, 32),    # MQA, non-pow2 seq
        (1, 80, 2, 2, 64, 32, 32),    # padded seq
    ],
)
def test_causal_matches_ref(b, s, h, kv, d, bq, bk):
    q, k, v = qkv(b, s, s, h, kv, d, seed=s + h)
    ref = attention_ref(q, k, v, causal=True)
    got = flash_attention_pallas(q, k, v, causal=True, block_q=bq, block_k=bk, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("window", [8, 32, 100])
def test_sliding_window(window):
    q, k, v = qkv(1, 96, 96, 2, 2, 32, seed=window)
    ref = attention_ref(q, k, v, causal=True, window=window)
    got = flash_attention_pallas(
        q, k, v, causal=True, window=window, block_q=32, block_k=32, interpret=True
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_blocked_attention_xla_path_matches_ref():
    """The pure-XLA streaming-softmax fallback (used in the CPU dry-run and
    under traced windows) must agree with the dense oracle too."""
    q, k, v = qkv(2, 64, 64, 4, 2, 16, seed=5)
    for window in (64, 16):
        ref = attention_ref(q, k, v, causal=True, window=window)
        got = blocked_attention(q, k, v, window=window, chunk=16)
        # blocked_attention matmuls in bf16 (TPU MXU dtype) -> looser tol
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-2, atol=2e-2)


def test_decode_alignment_q_offset():
    """Continuation chunks (q_offset > 0) must mask as absolute positions."""
    q, k, v = qkv(1, 16, 64, 2, 2, 16, seed=9)
    ref = attention_ref(q, k, v, causal=True)  # ref aligns q at sk - sq
    got = flash_attention_pallas(
        q, k, v, causal=True, q_offset=64 - 16, block_q=16, block_k=16, interpret=True
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=2e-5, atol=2e-5)


@settings(max_examples=8, deadline=None)
@given(
    s=st.integers(min_value=8, max_value=80),
    h=st.sampled_from([1, 2, 4]),
    window=st.one_of(st.none(), st.integers(min_value=4, max_value=64)),
    seed=st.integers(min_value=0, max_value=100),
)
def test_property_flash_equals_oracle(s, h, window, seed):
    q, k, v = qkv(1, s, s, h, h, 16, seed=seed)
    ref = attention_ref(q, k, v, causal=True, window=window)
    got = flash_attention_pallas(
        q, k, v, causal=True, window=window, block_q=16, block_k=16, interpret=True
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=3e-5, atol=3e-5)

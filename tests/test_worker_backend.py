"""WorkerBackend conformance suite (ISSUE 5, DESIGN.md §13).

The dispatch boundary's contract, asserted against BOTH shipped backends:

* **differential** — the same plans over the whole policy matrix produce
  bit-identical outputs through in-process Worker threads and through RPC
  worker processes (results crossing the boundary only as SharedStore
  keys; the integer workloads are collision-sensitive, so any wire/store
  rounding shows up as a wrong int, not a tolerance miss);
* **SA indices** — an adaptive StudyDriver study run on the process
  backend reproduces the thread-backend study's indices, CIs and decisions
  exactly, for every caching policy;
* **fault tolerance** — a SIGKILLed worker process's leases are
  re-enqueued (immediate dead-worker expiry) and completed by surviving
  workers; transient remote failures retry; permanent failures surface
  with the remote traceback;
* **straggler/backup races** and **exactly-once completion callbacks**
  behave identically on both backends (first completion wins);
* ``Manager.close()`` is idempotent and safe to race with ``drain()``.

Helpers are module-level and data-only where they must cross the spawn
boundary (worker processes re-import this module in a fresh interpreter).
"""

import os
import pathlib
import random
import signal
import threading
import time

import pytest

from repro.engine import ClusterSpec, execute_study, plan_study
from repro.engine.types import CACHING_POLICIES, POLICIES
from repro.runtime import (
    Manager,
    ProcessRpcBackend,
    RemoteTaskError,
    SocketBackend,
    WorkItem,
)
from repro.study import StudyDriver

from study_gen import (
    mix_study_build,
    naive_outputs,
    random_layout,
    random_param_sets,
    workflow_from_layout,
)

pytestmark = pytest.mark.filterwarnings("ignore::ResourceWarning")


# ---------------------------------------------------------------------------
# Spawn-picklable task functions for the Manager-level ("call" spec) tests
# ---------------------------------------------------------------------------


def _quick(tag):
    time.sleep(0.01)
    return f"q-{tag}"


def _hang_until_killed(marker_dir):
    """First execution anywhere in the fleet: record our pid and hang (the
    test SIGKILLs us). Every later execution returns immediately — the
    surviving worker's retry path."""
    marker = pathlib.Path(marker_dir) / "pid"
    if not marker.exists():
        marker.write_text(str(os.getpid()))
        time.sleep(60.0)
        return "hung"
    return "fast"


def _wedge_worker_process(marker_dir):
    """Worst-case teardown adversary: the TASK completes normally, but it
    leaves the worker process unable to exit — a non-daemon thread parked
    far past any test budget — and shrugs off SIGTERM. The stop frame ends
    the serve loop, then interpreter exit blocks joining the thread: only
    shutdown's terminate→KILL escalation can retire this process."""
    signal.signal(signal.SIGTERM, signal.SIG_IGN)
    threading.Thread(target=time.sleep, args=(300.0,), daemon=False).start()
    (pathlib.Path(marker_dir) / "stuck_pid").write_text(str(os.getpid()))
    return "wedged"


def _slow_once(marker_dir):
    """First execution straggles (but completes); the backup clone returns
    fast. Either may win — first completion wins."""
    marker = pathlib.Path(marker_dir) / "slow"
    try:
        # exclusive create = atomic "am I first" across processes
        fd = os.open(marker, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
        os.close(fd)
    except FileExistsError:
        return "fast"
    time.sleep(1.2)
    return "slow"


_FLAKY_CALLS = {"n": 0}  # per-process (workers re-import this module)


def _flaky_twice(x):
    if _FLAKY_CALLS["n"] < 2:
        _FLAKY_CALLS["n"] += 1
        raise RuntimeError("injected transient fault")
    return x * 2


def _boom():
    raise ValueError("boom: unconditional remote failure")


def _scalar_dict():
    # str-keyed dict of Python scalars: must round-trip the store with its
    # types intact (npz coercion would hand back 0-d arrays)
    return {"n": 2, "s": "x", "f": 0.5}


def _returns_none():
    return None  # a legal result; must not read as "missing from store"


def _mk_process_manager(tmp_path, n_workers=2, *, build=None, build_kwargs=None,
                        **mgr_kwargs):
    mgr = Manager(
        backend=ProcessRpcBackend(
            build=build,
            build_kwargs=build_kwargs,
            store_dir=str(tmp_path / "store"),
            heartbeat_interval=0.05,
        ),
        **mgr_kwargs,
    )
    mgr.start(n_workers)
    return mgr


# ---------------------------------------------------------------------------
# Differential: policy matrix × both backends, bit-identical to the oracle
# ---------------------------------------------------------------------------


def test_policy_matrix_bit_identical_across_backends(tmp_path):
    """One persistent process-backend session executes every policy's plan;
    outputs must equal the naive oracle AND the thread-backend run exactly
    (exact ints — any serialisation loss at the store/wire would wrap)."""
    rng = random.Random(1105)
    layout, names, cards = random_layout(rng, max_stages=3)
    wf = workflow_from_layout(layout)
    sets = random_param_sets(rng, names, cards, 12)
    inputs = [3, 8, 21]
    oracles = [naive_outputs(wf, sets, x) for x in inputs]

    mgr = _mk_process_manager(
        tmp_path, 2,
        build=mix_study_build,
        build_kwargs={"layout": layout, "inputs": inputs},
        enable_backup_tasks=False,
    )
    # third row of the matrix: a loopback TCP fleet over the object-store
    # tier — no shared working directory beyond the store root (§16)
    sock_mgr = Manager(
        backend=SocketBackend(
            build=mix_study_build,
            build_kwargs={"layout": layout, "inputs": inputs},
            store="obj:" + str(tmp_path / "objroot"),
            heartbeat_interval=0.05,
        ),
        enable_backup_tasks=False,
    )
    sock_mgr.start(2)
    try:
        for policy in POLICIES:
            plan = plan_study(wf, sets, policy=policy, max_bucket_size=3)
            thread_stream = execute_study(
                plan, inputs,
                cluster=ClusterSpec(n_workers=2, enable_backup_tasks=False),
            )
            proc_stream = execute_study(
                plan, inputs, manager=mgr, key_prefix=f"{policy}:"
            )
            sock_stream = execute_study(
                plan, inputs, manager=sock_mgr, key_prefix=f"{policy}:"
            )
            assert proc_stream.backend == "process"
            assert thread_stream.backend == "thread"
            assert sock_stream.backend == "socket"
            assert sum(proc_stream.dispatch_counts.values()) > 0
            assert sum(sock_stream.dispatch_counts.values()) > 0
            for i in range(len(inputs)):
                assert thread_stream.outputs[i] == oracles[i], (policy, i)
                assert proc_stream.outputs[i] == oracles[i], (policy, i)
                assert sock_stream.outputs[i] == oracles[i], (policy, i)
    finally:
        mgr.close()
        sock_mgr.close()


def test_results_cross_the_boundary_only_as_store_keys(tmp_path):
    """White-box: every process-backend result is committed to the shared
    store under its session-scoped work key — the completion message
    carries the key, and the hydrated value equals what the store serves
    (bit-exactly: a str survives as a str, not an array)."""
    mgr = _mk_process_manager(tmp_path, 1)
    try:
        mgr.submit(WorkItem(key="k0", spec=("call", _quick, ("x",), {})))
        mgr.drain()
        assert mgr.results()["k0"] == "q-x"
        store = mgr.backend.store
        committed = [k for k in store.committed_keys() if k.endswith(":k0")]
        assert len(committed) == 1
        assert committed[0].startswith("rpc:")  # session-scoped namespace
        assert store.get(committed[0]) == "q-x"
        # type-exact hydration: identical to what ThreadBackend would return
        mgr.submit(WorkItem(key="d0", spec=("call", _scalar_dict, (), {})))
        mgr.drain()
        d = mgr.results()["d0"]
        assert d == {"n": 2, "s": "x", "f": 0.5}
        assert type(d["n"]) is int and type(d["s"]) is str and type(d["f"]) is float
        # a None result succeeds (rides the completion as a marker), same
        # as ThreadBackend — not a retry-to-death "missing result"
        mgr.submit(WorkItem(key="n0", spec=("call", _returns_none, (), {})))
        mgr.drain()
        assert mgr.results()["n0"] is None
        assert mgr.retries == 0
    finally:
        mgr.close()


def test_restarted_backend_never_serves_a_stale_store_entry(tmp_path):
    """The same work key re-submitted through a RESTARTED backend over one
    store directory must recompute, not replay the previous session's
    committed value (store keys are session-scoped)."""
    backend = ProcessRpcBackend(store_dir=str(tmp_path / "store"),
                                heartbeat_interval=0.05)
    marker = tmp_path / "m"
    marker.mkdir()

    mgr1 = Manager(backend=backend)
    mgr1.start(1)
    mgr1.submit(WorkItem(key="k", spec=("call", _slow_once, (str(marker),), {})))
    mgr1.drain()
    assert mgr1.results()["k"] == "slow"  # first execution anywhere
    mgr1.close()

    mgr2 = Manager(backend=backend)
    mgr2.start(1)
    mgr2.submit(WorkItem(key="k", spec=("call", _slow_once, (str(marker),), {})))
    mgr2.drain()
    out = mgr2.results()["k"]
    mgr2.close()
    assert out == "fast", "second session served the first session's entry"


# ---------------------------------------------------------------------------
# SA indices: adaptive studies identical across backends, per caching policy
# ---------------------------------------------------------------------------


def _objective(leaf, _i):
    return float(leaf % 9973) / 9973.0


@pytest.mark.parametrize("policy", CACHING_POLICIES)
def test_sa_indices_bit_identical_thread_vs_process(tmp_path, policy):
    rng = random.Random(7000 + hash(policy) % 100)
    layout = [
        [("s0t0", (), 1.0, 64)],
        [
            ("s1t0", ("p0",), 1.0, 64),
            ("s1t1", ("p1",), 1.0, 64),
            ("s1t2", ("p2",), 1.0, 64),
        ],
    ]
    from repro.core import ParamSpace

    space = ParamSpace.from_dict({f"p{i}": [0, 1, 2] for i in range(3)})
    inputs = [rng.randrange(1000)]

    def run(backend):
        driver = StudyDriver(
            workflow_from_layout(layout),
            space,
            inputs,
            objective=_objective,
            seed=5,
            engine_policy=policy,
            cluster=ClusterSpec(n_workers=2),
            n_boot=8,
            backend=backend,
        )
        try:
            return driver.run(max_rounds=2)
        finally:
            driver.close()

    thread_state = run(None)
    proc_state = run(
        ProcessRpcBackend(
            build=mix_study_build,
            build_kwargs={"layout": layout, "inputs": inputs},
            store_dir=str(tmp_path / f"store-{policy}"),
        )
    )
    assert proc_state.evaluated == thread_state.evaluated
    assert len(proc_state.rounds) == len(thread_state.rounds) == 2
    for pr, tr in zip(proc_state.rounds, thread_state.rounds):
        assert pr.outputs == tr.outputs
        assert pr.analysis == tr.analysis  # indices + CIs, exact floats
        assert pr.decision == tr.decision
    assert proc_state.active == thread_state.active
    # the workers flushed their task caches at shutdown: the store dir
    # holds durable task-level entries (what a resumed study rehydrates),
    # while the transient rpc: transport payloads were purged
    store_dir = tmp_path / f"store-{policy}"
    assert any(store_dir.glob("*.npz")), "worker caches never flushed"


def test_sa_indices_bit_identical_thread_vs_socket(tmp_path):
    """The full adaptive loop over a TCP fleet + object store: indices,
    CIs, decisions and the active set must equal the thread run exactly —
    the multi-host acceptance row of ISSUE 8 (here on loopback)."""
    rng = random.Random(7042)
    layout = [
        [("s0t0", (), 1.0, 64)],
        [
            ("s1t0", ("p0",), 1.0, 64),
            ("s1t1", ("p1",), 1.0, 64),
            ("s1t2", ("p2",), 1.0, 64),
        ],
    ]
    from repro.core import ParamSpace

    space = ParamSpace.from_dict({f"p{i}": [0, 1, 2] for i in range(3)})
    inputs = [rng.randrange(1000)]

    def run(backend):
        driver = StudyDriver(
            workflow_from_layout(layout),
            space,
            inputs,
            objective=_objective,
            seed=5,
            engine_policy="hybrid",
            cluster=ClusterSpec(n_workers=2),
            n_boot=8,
            backend=backend,
        )
        try:
            return driver.run(max_rounds=2)
        finally:
            driver.close()

    thread_state = run(None)
    sock_state = run(
        SocketBackend(
            build=mix_study_build,
            build_kwargs={"layout": layout, "inputs": inputs},
            store="obj:" + str(tmp_path / "objroot"),
            heartbeat_interval=0.05,
        )
    )
    assert sock_state.evaluated == thread_state.evaluated
    assert len(sock_state.rounds) == len(thread_state.rounds) == 2
    for sr, tr in zip(sock_state.rounds, thread_state.rounds):
        assert sr.outputs == tr.outputs
        assert sr.analysis == tr.analysis  # indices + CIs, exact floats
        assert sr.decision == tr.decision
    assert sock_state.active == thread_state.active
    # the fleet's durable artifacts live under the object root as
    # footer-verified entries/ objects — no .npz scatter, no flocks
    entries = tmp_path / "objroot" / "entries"
    assert entries.is_dir() and any(entries.iterdir())


# ---------------------------------------------------------------------------
# Fault tolerance across the process boundary
# ---------------------------------------------------------------------------


def test_killed_worker_lease_reenqueued_and_completed_by_survivor(tmp_path):
    marker_dir = tmp_path / "marker"
    marker_dir.mkdir()
    mgr = _mk_process_manager(
        tmp_path, 2, enable_backup_tasks=False, max_attempts=3
    )
    try:
        mgr.submit(
            WorkItem(key="victim", spec=("call", _hang_until_killed,
                                         (str(marker_dir),), {}))
        )
        for i in range(3):
            mgr.submit(WorkItem(key=f"pad{i}", spec=("call", _quick, (i,), {})))
        pid_file = marker_dir / "pid"
        deadline = time.monotonic() + 30
        while not pid_file.exists():
            assert time.monotonic() < deadline, "hang task never started"
            time.sleep(0.02)
        victim_pid = int(pid_file.read_text())
        os.kill(victim_pid, signal.SIGKILL)
        mgr.drain()
        out = mgr.results()
        assert out["victim"] == "fast"  # re-run by a SURVIVING worker
        for i in range(3):
            assert out[f"pad{i}"] == f"q-{i}"
        assert mgr.heartbeat_expiries >= 1
        assert mgr.retries >= 1
        # the backend reports the victim dead; a survivor remains
        view = mgr.backend.heartbeat_view()
        assert sum(1 for st in view.values() if not st.alive) == 1
        assert sum(1 for st in view.values() if st.alive) == 1
        assert victim_pid in mgr.backend.worker_pids()
    finally:
        mgr.close()


def test_shutdown_bounded_even_with_stuck_worker(tmp_path):
    """``Manager.close()`` can never hang a fleet teardown: a worker whose
    process cannot exit after the stop frame (wedged by a non-daemon
    thread, SIGTERM ignored) is joined with a deadline, terminated, then
    KILLED at the escalation deadline — close returns in bounded
    wall-clock time and no worker process survives it. (Before the bound,
    shutdown's unconditional ``proc.join()`` waited on this forever.)"""
    marker_dir = tmp_path / "marker"
    marker_dir.mkdir()
    mgr = Manager(
        backend=ProcessRpcBackend(
            store_dir=str(tmp_path / "store"),
            heartbeat_interval=0.05,
            shutdown_grace=0.5,
        ),
        enable_backup_tasks=False,
    )
    mgr.start(2)
    closed = False
    try:
        mgr.submit(
            WorkItem(key="wedge", spec=("call", _wedge_worker_process,
                                        (str(marker_dir),), {}))
        )
        mgr.drain()  # the task itself completes fine
        assert mgr.results()["wedge"] == "wedged"
        pids = list(mgr.backend.worker_pids())
        t0 = time.monotonic()
        mgr.close()
        closed = True
        elapsed = time.monotonic() - t0
        # grace 0.5s + terminate(2s) + kill(1s) escalation windows, with
        # slack for process-table churn — far below the hung-join forever
        assert elapsed < 15.0, f"teardown took {elapsed:.1f}s"
        for pid in pids:
            deadline = time.monotonic() + 5
            while time.monotonic() < deadline:
                try:
                    os.kill(pid, 0)
                except ProcessLookupError:
                    break  # reaped
                time.sleep(0.05)
            else:
                pytest.fail(f"worker {pid} survived shutdown")
    finally:
        if not closed:
            mgr.close()


def test_transient_remote_failures_retry_to_success(tmp_path):
    mgr = _mk_process_manager(
        tmp_path, 1, enable_backup_tasks=False, max_attempts=5
    )
    try:
        mgr.submit(WorkItem(key="flaky", spec=("call", _flaky_twice, (21,), {})))
        mgr.drain()
        assert mgr.results()["flaky"] == 42
        assert mgr.retries == 2
    finally:
        mgr.close()


def test_permanent_remote_failure_carries_traceback(tmp_path):
    mgr = _mk_process_manager(
        tmp_path, 1, enable_backup_tasks=False, max_attempts=2
    )
    try:
        mgr.submit(WorkItem(key="bad", spec=("call", _boom, (), {})))
        mgr.drain()
        err = mgr.results()["bad"]
        assert isinstance(err, RemoteTaskError)
        assert isinstance(err, RuntimeError)  # streaming abort path re-raises
        assert "boom: unconditional remote failure" in str(err)
        assert "ValueError" in str(err)  # the remote traceback text
    finally:
        mgr.close()


@pytest.mark.parametrize("backend", ["thread", "process"])
def test_straggler_backup_and_exactly_once_callbacks(tmp_path, backend):
    """Identical straggler semantics on both backends: the slow attempt is
    cloned, first completion wins, and the per-key callback fires exactly
    once no matter how the race lands. Both backends execute the SAME
    spec-only WorkItems (ThreadBackend runs the portable call spec)."""
    marker_dir = tmp_path / f"m-{backend}"
    marker_dir.mkdir()
    counts = {}
    lock = threading.Lock()

    def cb(key, value):
        with lock:
            counts[key] = counts.get(key, 0) + 1

    if backend == "process":
        mgr = _mk_process_manager(
            tmp_path, 3, straggler_factor=0.5, max_attempts=4
        )
    else:
        mgr = Manager(straggler_factor=0.5, max_attempts=4)
        mgr.start(3)
    try:
        for i in range(6):
            mgr.submit(
                WorkItem(key=f"q{i}", spec=("call", _quick, (i,), {}),
                         callback=cb)
            )
        mgr.submit(
            WorkItem(key="strag", spec=("call", _slow_once,
                                        (str(marker_dir),), {}), callback=cb)
        )
        deadline = time.monotonic() + 60
        while "strag" not in mgr.results():
            assert time.monotonic() < deadline
            time.sleep(0.02)
        mgr.drain()
        out = mgr.results()
        assert out["strag"] in ("fast", "slow")
        assert all(c == 1 for c in counts.values()), counts
        assert set(counts) == {f"q{i}" for i in range(6)} | {"strag"}
    finally:
        mgr.close()


# ---------------------------------------------------------------------------
# ResultCache.flush() returns the persisted-entry count (ISSUE 5 satellite)
# ---------------------------------------------------------------------------


def test_result_cache_flush_returns_persist_count(tmp_path):
    from repro.engine import ResultCache
    from repro.runtime import HierarchicalStore

    store = HierarchicalStore(1 << 20, disk_dir=str(tmp_path / "s"))
    cache = ResultCache(1 << 20, spill_store=store)
    for i in range(3):
        cache.put(("k", i), float(i), 8)
    flushed = cache.flush()
    assert flushed == 3
    # a reopened store resolves everything the flush persisted
    reopened = HierarchicalStore(1 << 20, disk_dir=str(tmp_path / "s"))
    for i in range(3):
        assert reopened.get(repr(("k", i))) == float(i)
    # and without a spill store the flush is an explicit no-op zero
    assert ResultCache(1 << 20).flush() == 0


# ---------------------------------------------------------------------------
# Manager.close(): guarded state transition (ISSUE 5 satellite)
# ---------------------------------------------------------------------------


class TestCloseIdempotency:
    def test_double_close_and_close_without_start(self):
        mgr = Manager()
        mgr.start(2)
        mgr.submit(WorkItem(key="a", fn=lambda: 1))
        mgr.close()
        mgr.close()  # second close: no join of a retired pool, no error
        assert mgr.results()["a"] == 1
        assert not mgr.is_running

        never_started = Manager()
        never_started.close()
        never_started.close()

    def test_concurrent_close_from_many_threads(self):
        mgr = Manager()
        mgr.start(2)
        for i in range(8):
            mgr.submit(WorkItem(key=f"k{i}", fn=lambda i=i: i))
        errors = []

        def closer():
            try:
                mgr.close()
            except BaseException as e:  # noqa: BLE001
                errors.append(e)

        threads = [threading.Thread(target=closer) for _ in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=30)
            assert not t.is_alive(), "close() deadlocked"
        assert not errors
        assert len(mgr.results()) == 8

    def test_close_racing_drain(self):
        """drain() on one thread, close() on another, while slow work is in
        flight: both must return, all results must exist, nothing hangs."""
        mgr = Manager(enable_backup_tasks=False)
        mgr.start(2)
        for i in range(6):
            mgr.submit(
                WorkItem(key=f"s{i}", fn=lambda i=i: time.sleep(0.05) or i)
            )
        done = []

        def drainer():
            mgr.drain()
            done.append("drain")

        def closer():
            time.sleep(0.02)  # land mid-drain
            mgr.close()
            done.append("close")

        t1 = threading.Thread(target=drainer)
        t2 = threading.Thread(target=closer)
        t1.start()
        t2.start()
        t1.join(timeout=30)
        t2.join(timeout=30)
        assert not t1.is_alive() and not t2.is_alive(), "drain/close deadlock"
        assert sorted(done) == ["close", "drain"]
        assert len(mgr.results()) == 6
        with pytest.raises(RuntimeError):
            mgr.submit(WorkItem(key="late", fn=lambda: 1))

    def test_restart_after_close_is_a_fresh_session(self):
        mgr = Manager()
        mgr.submit(WorkItem(key="one", fn=lambda: 1))
        out = mgr.run(1, expected=1)
        assert out == {"one": 1}
        assert not mgr.is_running
        mgr.start(1)  # a closed Manager may host a fresh session
        mgr.submit(WorkItem(key="two", fn=lambda: 2))
        mgr.drain()
        mgr.close()
        assert mgr.results()["two"] == 2

"""Crash-safe, cross-process SharedStore suite (ISSUE 4).

Covers the on-disk protocol of DESIGN.md §12: atomic tmp+rename writes (a
killed writer leaves no readable garbage), footer-verified loads with
quarantine-on-corrupt (a poisoned directory self-heals by recomputing),
per-key file locks + manifest (no double-writes across processes), the
Manager.forget deferred-release fix, and the fleet acceptance: two
StudyDriver processes pooling one store directory produce bit-identical SA
indices to the single-process run with strictly fewer combined tasks than
two independent studies — and zero corrupt-entry reads after a mid-write
kill is injected.
"""

import json
import multiprocessing
import os
import pathlib
import threading
import time

import numpy as np
import pytest

from repro.core import ParamSpace, StageSpec, TaskSpec, Workflow
from repro.runtime.manager import Manager, WorkItem
from repro.runtime.storage import HierarchicalStore, SharedStore, stable_key
from repro.study import StudyDriver, run_fleet_study
from repro.study.state import StudyState

# ---------------------------------------------------------------------------
# Spawn-picklable helpers (must be module-level: fleet workers re-import
# this module in a fresh interpreter)
# ---------------------------------------------------------------------------

WEIGHTS = (8.0, 0.0, 2.0, 0.01)
SPACE_DICT = {f"p{i}": [0.0, 1.0, 2.0, 3.0] for i in range(4)}
SPACE = ParamSpace.from_dict(SPACE_DICT)


def tiny_build():
    """Fleet ``build`` for the 2-stage synthetic workflow used across the
    driver tests: param-free norm (×2) then 4 seg tasks adding w_i·p_i."""

    def make_fn(i):
        def fn(x, **kw):
            return x + WEIGHTS[i] * sum(kw.values())

        return fn

    norm = StageSpec(
        name="norm",
        tasks=(TaskSpec("normalize", (), fn=lambda x: x * 2.0, cost=1.0,
                        output_bytes=8),),
    )
    seg = StageSpec(
        name="seg",
        tasks=tuple(
            TaskSpec(name=f"seg_t{i}", param_names=(f"p{i}",), fn=make_fn(i),
                     cost=1.0, output_bytes=64)
            for i in range(4)
        ),
    )
    return {
        "workflow": Workflow(stages=(norm, seg)),
        "space": SPACE,
        "inputs": [1.0],
        "objective": lambda out, i: float(out),
    }


def _stress_writer(store_dir: str, writer: int, n_keys: int, n_iters: int) -> None:
    """Hammer one store directory with overlapping keys; record what this
    process observed into a per-writer report file."""
    store = SharedStore(1 << 20, disk_dir=store_dir, writer_id=f"w{writer}")
    bad_reads = 0
    for it in range(n_iters):
        for k in range(n_keys):
            key = f"stress:{k}"
            value = np.full((64,), float(k), np.float32)
            store.put(key, value)
            store.persist(key)
            got = store.get(key)
            if got is None or not np.array_equal(np.asarray(got), value):
                bad_reads += 1
    report = {
        "bad_reads": bad_reads,
        "corrupt": store.corrupt,
        "dedup_writes": store.dedup_writes,
    }
    out = pathlib.Path(store_dir) / f"report_w{writer}.json"
    out.write_text(json.dumps(report))


def _killed_writer(store_dir: str, kill_on: int) -> None:
    """Write entries until the ``kill_on``-th disk write, then die between
    tmp-write and rename — the torn-write window a SIGKILL lands in."""
    store = SharedStore(1 << 20, disk_dir=store_dir, writer_id="victim")
    writes = {"n": 0}

    def fault(tmp_path):
        writes["n"] += 1
        if writes["n"] >= kill_on:
            os._exit(42)  # hard kill: no cleanup, tmp file left behind

    store.fault_after_tmp_write = fault
    for k in range(kill_on + 5):
        store.put(f"victim:{k}", np.full((32,), float(k), np.float32))
        store.persist(f"victim:{k}")
    os._exit(0)  # unreachable when kill_on fires


# ---------------------------------------------------------------------------
# Atomic writes
# ---------------------------------------------------------------------------


class TestAtomicWrites:
    def test_crash_between_tmp_and_rename_leaves_no_entry(self, tmp_path):
        """The satellite bugfix: np.savez used to write in place, so a
        mid-write crash left a truncated entry. Now the final name appears
        only via os.replace — a fault before the rename leaves nothing."""
        store = HierarchicalStore(1 << 20, disk_dir=str(tmp_path))

        def boom(tmp):
            raise RuntimeError("simulated kill")

        store.fault_after_tmp_write = boom
        store.put("k", np.arange(32, dtype=np.float32))
        with pytest.raises(RuntimeError, match="simulated kill"):
            store.persist("k")

        reopened = HierarchicalStore(1 << 20, disk_dir=str(tmp_path))
        assert not reopened.contains("k")
        assert reopened.get("k") is None
        assert reopened.corrupt == 0  # orphan tmp is ignored, not corrupt
        assert list(tmp_path.glob("*.tmp"))  # the orphan is still there

        # recompute-on-miss: a clean rewrite publishes normally
        store.fault_after_tmp_write = None
        store.persist("k")
        fresh = HierarchicalStore(1 << 20, disk_dir=str(tmp_path))
        np.testing.assert_array_equal(
            np.asarray(fresh.get("k")), np.arange(32, dtype=np.float32)
        )

    def test_rewrite_over_existing_entry_is_atomic(self, tmp_path):
        store = HierarchicalStore(1 << 20, disk_dir=str(tmp_path))
        store.put("k", np.zeros(8, np.float32))
        store.persist("k")

        def boom(tmp):
            raise RuntimeError("kill")

        store.fault_after_tmp_write = boom
        with pytest.raises(RuntimeError):
            store.persist("k")
        # the previous complete entry survives the torn rewrite
        reopened = HierarchicalStore(1 << 20, disk_dir=str(tmp_path))
        np.testing.assert_array_equal(
            np.asarray(reopened.get("k")), np.zeros(8, np.float32)
        )
        assert reopened.corrupt == 0


# ---------------------------------------------------------------------------
# Corruption detection + quarantine
# ---------------------------------------------------------------------------


class TestCorruptionQuarantine:
    def _entry_path(self, tmp_path, key):
        return tmp_path / f"{stable_key(key)}.npz"

    def _poisoned_store(self, tmp_path, mutate):
        store = HierarchicalStore(1 << 20, disk_dir=str(tmp_path))
        store.put("k", np.arange(64, dtype=np.float32))
        store.persist("k")
        path = self._entry_path(tmp_path, "k")
        mutate(path)
        return HierarchicalStore(1 << 20, disk_dir=str(tmp_path))

    @pytest.mark.parametrize(
        "mutate",
        [
            pytest.param(lambda p: p.write_bytes(b""), id="zero-byte"),
            pytest.param(
                lambda p: p.write_bytes(p.read_bytes()[: p.stat().st_size // 2]),
                id="truncated",
            ),
            pytest.param(lambda p: p.write_bytes(b"garbage" * 100), id="garbage"),
        ],
    )
    def test_bad_entry_is_a_miss_and_quarantined(self, tmp_path, mutate):
        reopened = self._poisoned_store(tmp_path, mutate)
        assert reopened.get("k") is None
        assert reopened.misses == 1
        assert reopened.corrupt == 1
        assert not self._entry_path(tmp_path, "k").exists()  # moved aside
        assert list((tmp_path / "quarantine").iterdir())
        # self-heal: recompute-on-miss republishes a valid entry
        reopened.put("k", np.arange(64, dtype=np.float32))
        reopened.persist("k")
        fresh = HierarchicalStore(1 << 20, disk_dir=str(tmp_path))
        assert fresh.contains("k")
        np.testing.assert_array_equal(
            np.asarray(fresh.get("k")), np.arange(64, dtype=np.float32)
        )
        assert fresh.corrupt == 0

    def test_bitflip_fails_sha_check(self, tmp_path):
        def flip(p):
            data = bytearray(p.read_bytes())
            data[len(data) // 3] ^= 0xFF
            p.write_bytes(bytes(data))

        reopened = self._poisoned_store(tmp_path, flip)
        assert reopened.get("k") is None
        assert reopened.corrupt == 1

    def test_contains_does_not_trust_exists(self, tmp_path):
        """The satellite bugfix: contains() used to be path.exists(), so a
        torn entry read as present and the later np.load crashed."""
        reopened = self._poisoned_store(tmp_path, lambda p: p.write_bytes(b""))
        assert self._entry_path(tmp_path, "k").exists()  # the torn entry IS there
        assert not reopened.contains("k")
        assert reopened.corrupt == 1
        assert not self._entry_path(tmp_path, "k").exists()  # quarantined

    def test_legacy_footerless_entry_still_resumes(self, tmp_path):
        """Migration: entries written before the footer protocol (plain
        np.savez, no footer) must still load — np.load is their verifier —
        so a pre-footer store directory resumes with zero recomputation
        and zero corrupt counts."""
        value = np.arange(24, dtype=np.float32)
        legacy = tmp_path / f"{stable_key('old')}.npz"
        np.savez(legacy, __value__=value)  # the old in-place write format
        store = HierarchicalStore(1 << 20, disk_dir=str(tmp_path))
        assert store.contains("old")
        np.testing.assert_array_equal(np.asarray(store.get("old")), value)
        assert store.corrupt == 0 and store.disk_hits == 1

    def test_torn_legacy_entry_is_corrupt(self, tmp_path):
        value = np.arange(512, dtype=np.float32)
        legacy = tmp_path / f"{stable_key('old')}.npz"
        np.savez(legacy, __value__=value)
        legacy.write_bytes(legacy.read_bytes()[: legacy.stat().st_size // 2])
        store = HierarchicalStore(1 << 20, disk_dir=str(tmp_path))
        assert store.get("old") is None
        assert store.corrupt == 1

    def test_valid_entries_unaffected_by_neighbor_corruption(self, tmp_path):
        store = HierarchicalStore(1 << 20, disk_dir=str(tmp_path))
        store.put("good", np.ones(16, np.float32))
        store.put("bad", np.ones(16, np.float32))
        store.persist_all()
        (tmp_path / f"{stable_key('bad')}.npz").write_bytes(b"x")
        reopened = HierarchicalStore(1 << 20, disk_dir=str(tmp_path))
        np.testing.assert_array_equal(
            np.asarray(reopened.get("good")), np.ones(16, np.float32)
        )
        assert reopened.get("bad") is None
        assert reopened.corrupt == 1


# ---------------------------------------------------------------------------
# SharedStore: locks + manifest
# ---------------------------------------------------------------------------


class TestSharedStore:
    def test_manifest_records_commits_last_writer_wins(self, tmp_path):
        s1 = SharedStore(1 << 20, disk_dir=str(tmp_path), writer_id="w1")
        s1.put("a", np.ones(8, np.float32))
        s1.put("b", np.zeros(8, np.float32))
        s1.persist_all()
        assert s1.committed_keys() == {"a", "b"}
        records = s1.manifest_records()
        assert records["a"]["writer"] == "w1"
        assert records["a"]["sha"] == stable_key("a")

    def test_second_writer_skips_committed_entry(self, tmp_path):
        s1 = SharedStore(1 << 20, disk_dir=str(tmp_path), writer_id="w1")
        s1.put("x", np.ones(8, np.float32))
        s1.persist("x")
        s2 = SharedStore(1 << 20, disk_dir=str(tmp_path), writer_id="w2")
        s2.put("x", np.ones(8, np.float32))
        s2.persist("x")
        assert s2.dedup_writes == 1
        # one manifest record: the dedup'd write never appended
        assert [r["writer"] for r in s2.manifest_records().values()] == ["w1"]

    def test_torn_manifest_line_is_skipped(self, tmp_path):
        s1 = SharedStore(1 << 20, disk_dir=str(tmp_path), writer_id="w1")
        s1.put("a", np.ones(8, np.float32))
        s1.persist("a")
        with open(tmp_path / "manifest.jsonl", "a") as f:
            f.write('{"key": "torn-half')  # killed appender
        s2 = SharedStore(1 << 20, disk_dir=str(tmp_path))
        assert s2.committed_keys() == {"a"}

    def test_append_after_truncated_tail_does_not_poison_replay(self, tmp_path):
        """Crash mid-append leaves a partial final line WITHOUT a newline;
        the next writer's append must repair the tail (terminate the torn
        line) instead of concatenating onto it — otherwise the torn bytes
        swallow the NEW record and replay loses a committed key."""
        s1 = SharedStore(1 << 20, disk_dir=str(tmp_path), writer_id="w1")
        s1.put("a", np.ones(8, np.float32))
        s1.persist("a")
        manifest = tmp_path / "manifest.jsonl"
        with open(manifest, "a") as f:
            f.write('{"key": "torn-half')  # killed mid-append, no newline
        s2 = SharedStore(1 << 20, disk_dir=str(tmp_path), writer_id="w2")
        s2.put("b", np.zeros(8, np.float32))
        s2.persist("b")
        assert s2.committed_keys() == {"a", "b"}
        records = s2.manifest_records()
        assert records["b"]["writer"] == "w2"
        # replay across a fresh mount agrees (the repair is on disk)
        s3 = SharedStore(1 << 20, disk_dir=str(tmp_path))
        assert s3.committed_keys() == {"a", "b"}
        # the torn line was terminated, not extended: three distinct lines,
        # with the partial one isolated in the middle
        lines = manifest.read_text().splitlines()
        assert len(lines) == 3
        assert lines[1] == '{"key": "torn-half'

    def test_quarantined_entry_recommitted_after_recompute(self, tmp_path):
        s1 = SharedStore(1 << 20, disk_dir=str(tmp_path), writer_id="w1")
        s1.put("x", np.ones(8, np.float32))
        s1.persist("x")
        (tmp_path / f"{stable_key('x')}.npz").write_bytes(b"")
        s2 = SharedStore(1 << 20, disk_dir=str(tmp_path), writer_id="w2")
        assert s2.get("x") is None and s2.corrupt == 1
        s2.put("x", np.ones(8, np.float32))
        s2.persist("x")  # entry gone from disk -> real rewrite, new manifest row
        assert s2.manifest_records()["x"]["writer"] == "w2"
        s3 = SharedStore(1 << 20, disk_dir=str(tmp_path))
        np.testing.assert_array_equal(
            np.asarray(s3.get("x")), np.ones(8, np.float32)
        )

    def test_torn_legacy_entry_repaired_on_write(self, tmp_path):
        """A torn pre-footer file under a key's final name must not block
        the commit of a freshly recomputed value: the write-path probe is
        strict (footer required), so the torn bytes are overwritten."""
        torn = tmp_path / f"{stable_key('x')}.npz"
        torn.write_bytes(b"half-an-old-npz-archive" * 4)  # >= footer size
        s = SharedStore(1 << 20, disk_dir=str(tmp_path), writer_id="w1")
        s.put("x", np.ones(8, np.float32))
        s.persist("x")
        assert s.dedup_writes == 0  # torn entry did NOT read as committed
        assert s.committed_keys() == {"x"}
        fresh = SharedStore(1 << 20, disk_dir=str(tmp_path))
        np.testing.assert_array_equal(
            np.asarray(fresh.get("x")), np.ones(8, np.float32)
        )
        assert fresh.corrupt == 0

    def test_repeated_flush_skips_own_committed_entries(self, tmp_path):
        """persist_all is called once per fleet round; already-committed
        entries are skipped via the persisted-keys fast path and are NOT
        counted as dedup_writes (that counter means a PEER won the race)."""
        s = SharedStore(1 << 20, disk_dir=str(tmp_path), writer_id="w1")
        for i in range(4):
            s.put(f"k{i}", np.full((8,), i, np.float32))
        s.persist_all()
        s.persist_all()
        s.persist_all()
        assert s.dedup_writes == 0
        assert len(s.manifest_records()) == 4

    def test_intra_process_writer_threads_exclude_each_other(self, tmp_path):
        """flock is taken on a fresh fd per write, so two stores in ONE
        process (threads) also serialise on a key."""
        stores = [
            SharedStore(1 << 20, disk_dir=str(tmp_path), writer_id=f"t{i}")
            for i in range(2)
        ]
        errs = []

        def work(s):
            try:
                for it in range(20):
                    s.put("hot", np.full((128,), it, np.float32))
                    s.persist("hot")
                    assert s.get("hot") is not None
            except Exception as e:  # noqa: BLE001
                errs.append(e)

        threads = [threading.Thread(target=work, args=(s,)) for s in stores]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert errs == []
        fresh = SharedStore(1 << 20, disk_dir=str(tmp_path))
        assert fresh.get("hot") is not None and fresh.corrupt == 0

    def test_two_process_stress_no_corrupt_reads(self, tmp_path):
        """Acceptance: two processes hammering one directory with
        overlapping keys — every read sees a complete entry, zero corrupt."""
        ctx = multiprocessing.get_context("spawn")
        procs = [
            ctx.Process(target=_stress_writer, args=(str(tmp_path), i, 8, 4))
            for i in range(2)
        ]
        for p in procs:
            p.start()
        for p in procs:
            p.join(timeout=120)
            assert p.exitcode == 0
        reports = [
            json.loads((tmp_path / f"report_w{i}.json").read_text())
            for i in range(2)
        ]
        assert all(r["bad_reads"] == 0 for r in reports)
        assert all(r["corrupt"] == 0 for r in reports)
        # and the directory is fully readable afterwards
        fresh = SharedStore(1 << 20, disk_dir=str(tmp_path))
        for k in range(8):
            got = fresh.get(f"stress:{k}")
            np.testing.assert_array_equal(
                np.asarray(got), np.full((64,), float(k), np.float32)
            )
        assert fresh.corrupt == 0

    def test_killed_writer_mid_write_poisons_nothing(self, tmp_path):
        """Acceptance: kill a writer in the tmp-write→rename window, reopen
        the directory — zero corrupt reads, the unpublished key is a miss
        (recompute-on-miss), every previously-committed key still loads."""
        ctx = multiprocessing.get_context("spawn")
        p = ctx.Process(target=_killed_writer, args=(str(tmp_path), 5))
        p.start()
        p.join(timeout=120)
        assert p.exitcode == 42  # died inside the torn-write window
        assert list(tmp_path.glob("*.tmp"))  # the torn write's leftover

        fresh = SharedStore(1 << 20, disk_dir=str(tmp_path))
        served = 0
        for k in range(10):
            got = fresh.get(f"victim:{k}")
            if got is not None:
                served += 1
                np.testing.assert_array_equal(
                    np.asarray(got), np.full((32,), float(k), np.float32)
                )
        assert fresh.corrupt == 0  # zero corrupt-entry reads
        assert served < 10  # the in-flight write (and later ones) are misses
        # manifest agrees with what is actually readable
        assert len(fresh.committed_keys()) == served


# ---------------------------------------------------------------------------
# Manager.forget deferred release (satellite bugfix)
# ---------------------------------------------------------------------------


class TestForgetLeasedKeys:
    def test_forget_while_leased_releases_after_settle(self):
        release = threading.Event()
        entered = threading.Event()

        def slow():
            entered.set()
            release.wait(10)
            return "v"

        mgr = Manager(enable_backup_tasks=False)
        mgr.start(1)
        try:
            mgr.submit(WorkItem(key="slow", fn=slow))
            assert entered.wait(5)  # the lease is now held
            mgr.forget(["slow"])
            with mgr._lock:
                assert "slow" in mgr._deferred_forget
            release.set()
            mgr.drain()
            deadline = time.monotonic() + 5
            while time.monotonic() < deadline:
                with mgr._lock:
                    if (
                        not mgr._results
                        and not mgr._attempt_seq
                        and not mgr._deferred_forget
                    ):
                        break
                time.sleep(0.01)
            assert mgr.results() == {}
            with mgr._lock:
                assert mgr._attempt_seq == {}
                assert mgr._callbacks == {}
                assert mgr._deferred_forget == set()
        finally:
            release.set()
            mgr.close()

    def test_forget_settled_keys_still_immediate(self):
        mgr = Manager(enable_backup_tasks=False)
        mgr.start(1)
        try:
            mgr.submit(WorkItem(key="a", fn=lambda: 1))
            mgr.drain()
            assert mgr.results() == {"a": 1}
            mgr.forget(["a"])
            assert mgr.results() == {}
            with mgr._lock:
                assert mgr._attempt_seq == {}
        finally:
            mgr.close()


# ---------------------------------------------------------------------------
# Fleet study acceptance
# ---------------------------------------------------------------------------


def _single_process_state(max_rounds):
    build = tiny_build()
    driver = StudyDriver(
        build["workflow"], build["space"], build["inputs"],
        objective=build["objective"], seed=13, n_boot=16,
    )
    try:
        return driver.run(max_rounds=max_rounds)
    finally:
        driver.close()


class TestFleetStudy:
    MAX_ROUNDS = 3

    def test_fleet_bit_identical_and_strictly_fewer_combined_tasks(
        self, tmp_path
    ):
        """ISSUE 4 acceptance: two StudyDriver processes pooling one store
        directory — bit-identical SA indices to the single-process run,
        strictly fewer combined tasks than 2 independent studies, zero
        corrupt reads."""
        single = _single_process_state(self.MAX_ROUNDS)
        fleet_state, fleet = run_fleet_study(
            tiny_build,
            n_procs=2,
            store_dir=str(tmp_path / "store"),
            max_rounds=self.MAX_ROUNDS,
            seed=13,
            n_boot=16,
        )
        # bit-identical objectives and SA indices, round by round
        assert fleet_state.evaluated == single.evaluated
        assert len(fleet_state.rounds) == len(single.rounds)
        for fr, sr in zip(fleet_state.rounds, single.rounds):
            assert fr.kind == sr.kind
            assert fr.param_sets == sr.param_sets
            assert fr.outputs == sr.outputs  # bit-identical objectives
            assert fr.analysis == sr.analysis  # bit-identical indices
            assert fr.decision == sr.decision
        assert fleet_state.active == single.active
        assert fleet_state.best == single.best

        # strictly fewer combined tasks than 2 independent studies
        independent_total = 2 * single.tasks_executed
        assert 0 < fleet["tasks_executed"] < independent_total

        # zero corrupt-entry reads anywhere in the fleet
        assert fleet["corrupt"] == 0
        assert fleet["committed_keys"] > 0

    def test_fleet_on_a_directory_with_an_injected_mid_write_kill(
        self, tmp_path
    ):
        """Acceptance tail: inject a mid-write kill into the store dir
        FIRST, then run the fleet on the poisoned directory — it completes
        with zero corrupt reads and the same results (self-heal by
        recompute)."""
        store_dir = tmp_path / "store"
        store_dir.mkdir()
        ctx = multiprocessing.get_context("spawn")
        p = ctx.Process(target=_killed_writer, args=(str(store_dir), 2))
        p.start()
        p.join(timeout=120)
        assert p.exitcode == 42
        assert list(store_dir.glob("*.tmp"))

        single = _single_process_state(2)
        fleet_state, fleet = run_fleet_study(
            tiny_build,
            n_procs=2,
            store_dir=str(store_dir),
            max_rounds=2,
            seed=13,
            n_boot=16,
        )
        assert fleet["corrupt"] == 0
        assert fleet_state.evaluated == single.evaluated
        for fr, sr in zip(fleet_state.rounds, single.rounds):
            assert fr.outputs == sr.outputs and fr.analysis == sr.analysis

    def test_fleet_round_records_account_all_shards(self, tmp_path):
        fleet_state, fleet = run_fleet_study(
            tiny_build,
            n_procs=2,
            store_dir=str(tmp_path / "store"),
            max_rounds=2,
            seed=13,
            n_boot=16,
        )
        assert fleet_state.tasks_executed == fleet["tasks_executed"]
        for r in fleet_state.rounds:
            assert r.n_proposed > 0
            assert r.tasks_executed >= 0
        # the leader state checkpoints like any StudyState
        ckpt = tmp_path / "state.json"
        fleet_state.save(str(ckpt))
        st2 = StudyState.load(str(ckpt))
        assert st2.evaluated == fleet_state.evaluated
        assert st2.ledger.to_list() == fleet_state.ledger.to_list()


# ---------------------------------------------------------------------------
# Lock-discipline regressions (PR 9): disk I/O must not run under the
# store lock. These pin the behavior the static analyzer flagged — a slow
# disk probe or unlink must never stall RAM-tier readers on other threads.
# ---------------------------------------------------------------------------


class _GatedDiskStore(HierarchicalStore):
    """HierarchicalStore whose disk presence probe blocks on an event —
    models a slow/contended filesystem (NFS stall, flocked quarantine)."""

    def __init__(self, tmp):
        super().__init__(1 << 20, disk_dir=str(tmp))
        self.probe_entered = threading.Event()
        self.probe_gate = threading.Event()

    def _disk_entry_ok(self, path):
        self.probe_entered.set()
        assert self.probe_gate.wait(10), "probe gate never released"
        return False


class TestStoreLockDiscipline:
    def test_slow_disk_probe_does_not_stall_ram_tier(self, tmp_path):
        """contains() used to hold the store lock across the disk probe:
        one slow footer read serialized every put/get in the process."""
        store = _GatedDiskStore(tmp_path / "store")
        t = threading.Thread(target=store.contains, args=("absent-key",))
        t.start()
        try:
            assert store.probe_entered.wait(10)
            # the probe is parked mid-I/O; the RAM tier must stay live
            t0 = time.monotonic()
            store.put("hot", np.arange(4))
            assert store.get("hot") is not None
            assert store.counters()["hits"] == 1
            assert time.monotonic() - t0 < 5.0, (
                "RAM-tier ops blocked behind the disk probe: contains() is "
                "holding the store lock across disk I/O again"
            )
        finally:
            store.probe_gate.set()
            t.join(10)
        assert not t.is_alive()

    def test_delete_removes_both_tiers(self, tmp_path):
        store = HierarchicalStore(1 << 20, disk_dir=str(tmp_path / "store"))
        store.put("k", np.arange(8))
        store.persist_all()
        assert store.contains("k")
        store.delete("k")
        assert not store.contains("k")
        assert store.get("k") is None
        # idempotent: a second delete of a gone key is a no-op, not an error
        store.delete("k")

"""SA-over-serving reuse integration + optimizer + chunked-XLA ssm tests."""

import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.core.sa_serve import build_serve_stage, run_sa_serve
from repro.core import Workflow
from repro.kernels.ref import ssm_scan_ref, ssm_scan_xla
from repro.models import init_params
from repro.optim import OptConfig, adamw_init, adamw_update


@pytest.fixture(scope="module")
def serve_setup():
    cfg = reduced_config(get_config("gemma3_1b"))
    params = init_params(cfg, jax.random.key(1))
    rng = np.random.default_rng(2)
    prompts = {
        pid: rng.integers(0, cfg.vocab_size, (1, 8)).astype(np.int32)
        for pid in range(2)
    }
    sets = [
        tuple(sorted({"prompt_id": pid, "rep_penalty": rp, "top_k": tk,
                      "threshold": th}.items()))
        for pid, rp, tk, th in itertools.product(
            range(2), (1.0, 1.2), (4,), (0.2, 0.4)
        )
    ]
    return cfg, params, prompts, sets


class TestSaServe:
    def test_reuse_counts(self, serve_setup):
        cfg, params, prompts, sets = serve_setup
        out = run_sa_serve(cfg, params, prompts, sets, gen_len=3, max_len=24)
        # 8 sets: 2 prefills + 4 generates + 8 scores = 14 of 24 tasks
        assert out["tasks_total"] == 24
        assert out["tasks_executed"] == 14
        assert out["reuse_fraction"] > 0.4

    def test_reused_equals_naive(self, serve_setup):
        """Reuse must not change results: execute each set independently and
        compare accept rates."""
        cfg, params, prompts, sets = serve_setup
        out = run_sa_serve(cfg, params, prompts, sets, gen_len=3, max_len=24)
        stage = build_serve_stage(cfg, params, prompts, gen_len=3, max_len=24)
        for rid, ps in enumerate(sets):
            state = {}
            d = dict(ps)
            for t in stage.tasks:
                state = t.fn(state, **{k: d[k] for k in t.param_names})
            assert out["accept_rate"][rid] == pytest.approx(
                float(state["accept_rate"]), abs=1e-6
            )

    def test_memory_budget_bounds_paths(self, serve_setup):
        cfg, params, prompts, sets = serve_setup
        stage = build_serve_stage(cfg, params, prompts, gen_len=3, max_len=24)
        cache_b = stage.tasks[0].output_bytes
        out = run_sa_serve(
            cfg, params, prompts, sets, gen_len=3, max_len=24,
            hbm_budget_bytes=3 * cache_b,
        )
        assert out["peak_bytes"] <= 3 * cache_b


class TestAdamW:
    def test_converges_on_quadratic(self):
        params = {"x": jnp.array([3.0, -2.0])}
        state = adamw_init(params)
        cfg = OptConfig(lr=0.1, weight_decay=0.0, warmup_steps=1, total_steps=200)
        loss = lambda p: jnp.sum(jnp.square(p["x"]))
        for _ in range(150):
            g = jax.grad(loss)(params)
            params, state, _ = adamw_update(g, state, params, cfg)
        assert float(loss(params)) < 1e-2

    def test_clipping_and_metrics(self):
        params = {"x": jnp.ones(3)}
        state = adamw_init(params)
        cfg = OptConfig(clip_norm=0.5)
        g = {"x": jnp.full((3,), 100.0)}
        _, _, metrics = adamw_update(g, state, params, cfg)
        assert float(metrics["grad_norm"]) > 100.0
        assert float(metrics["lr"]) >= 0.0


class TestSsmXla:
    @pytest.mark.parametrize("per_channel", [False, True])
    @pytest.mark.parametrize("s,chunk", [(17, 8), (64, 16), (33, 64)])
    def test_chunked_xla_matches_ref(self, per_channel, s, chunk):
        rng = np.random.default_rng(s + chunk)
        b, h, n, p = 2, 2, 8, 8
        x = jnp.asarray(rng.normal(0, 1, (b, s, h, p)).astype(np.float32))
        a_shape = (b, s, h, n) if per_channel else (b, s, h)
        a = jnp.asarray(np.exp(-np.exp(rng.normal(-1, 0.5, a_shape))).astype(np.float32))
        bb = jnp.asarray(rng.normal(0, 0.5, (b, s, h, n)).astype(np.float32))
        c = jnp.asarray(rng.normal(0, 0.5, (b, s, h, n)).astype(np.float32))
        y_ref, h_ref = ssm_scan_ref(x, a, bb, c)
        y, hf = ssm_scan_xla(x, a, bb, c, chunk=chunk)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(hf), np.asarray(h_ref), rtol=2e-4, atol=2e-4)

"""Cancellation race suite (DESIGN.md §18, ISSUE 10 satellite).

``Manager.cancel`` must deliver exactly-once semantics under every race
the service can produce — cancel while queued, cancel mid-lease, cancel
while the key sits in a delegated sub-queue (the steal surface),
double-cancel, cancel-then-resubmit — and behave identically on the
thread, process and socket backends. Each revoked key's callback fires
exactly once with :class:`TaskCancelled`; a poisoned lease's eventual
completion is dropped (never a second callback, never a resurrected
result); and a cancel-forget-resubmit cycle produces the bit-identical
value an uncancelled run would have.
"""

import threading
import time

import pytest

from repro.runtime import (
    Manager,
    ProcessRpcBackend,
    SocketBackend,
    TaskCancelled,
    WorkItem,
)

pytestmark = pytest.mark.filterwarnings("ignore::ResourceWarning")

BACKENDS = ["thread", "process", "socket"]


def _mk_manager(backend, tmp_path, n_workers=2, **mgr_kwargs):
    if backend == "thread":
        mgr = Manager(**mgr_kwargs)
    elif backend == "process":
        mgr = Manager(
            backend=ProcessRpcBackend(
                store_dir=str(tmp_path / "store"),
                heartbeat_interval=0.05,
            ),
            **mgr_kwargs,
        )
    else:
        mgr = Manager(
            backend=SocketBackend(
                store="obj:" + str(tmp_path / "objroot"),
                heartbeat_interval=0.05,
            ),
            **mgr_kwargs,
        )
    mgr.start(n_workers)
    return mgr


# Spawn-picklable task bodies (worker processes re-import this module).


def _double(x):
    return x * 2


def _napper(seconds):
    time.sleep(seconds)
    return "napped"


class _Recorder:
    """Per-key callback journal: every (key, value) settlement in order."""

    def __init__(self):
        self.lock = threading.Lock()
        self.events = {}

    def cb(self, key, value):
        with self.lock:
            self.events.setdefault(key, []).append(value)

    def count(self, key):
        with self.lock:
            return len(self.events.get(key, []))

    def only(self, key):
        with self.lock:
            (value,) = self.events[key]
            return value


@pytest.mark.parametrize("backend", BACKENDS)
def test_cancel_race_matrix(tmp_path, backend):
    """The five-race gauntlet in one session per backend: queued cancel,
    mid-lease cancel, double-cancel, unrelated work undisturbed, then
    cancel→forget→resubmit yielding the bit-identical uncancelled value."""
    rec = _Recorder()
    mgr = _mk_manager(
        backend, tmp_path, n_workers=2, enable_backup_tasks=False
    )
    try:
        # Occupy both workers so later submissions stay QUEUED.
        for i in range(2):
            mgr.submit(
                WorkItem(
                    key=f"blk{i}",
                    spec=("call", _napper, (1.0,), {}),
                    callback=rec.cb,
                )
            )
        deadline = time.monotonic() + 30
        while sum(mgr.dispatch_counts.values()) < 2:
            assert time.monotonic() < deadline, "blockers never leased"
            time.sleep(0.01)
        for i in range(4):
            mgr.submit(
                WorkItem(
                    key=f"q{i}",
                    spec=("call", _double, (i,), {}),
                    callback=rec.cb,
                )
            )

        # Race 1: cancel while queued — purged before any lease exists.
        cancelled = mgr.cancel(["q0", "q1"])
        assert sorted(cancelled) == ["q0", "q1"]
        assert isinstance(rec.only("q0"), TaskCancelled)
        assert isinstance(rec.only("q1"), TaskCancelled)

        # Race 2: cancel mid-lease — the blocker's lease is poisoned; its
        # callback fires TaskCancelled NOW, and the worker's eventual
        # completion (it is still sleeping) must be dropped on arrival.
        assert mgr.cancel(["blk0"]) == ["blk0"]
        assert isinstance(rec.only("blk0"), TaskCancelled)

        # Race 3: double-cancel — second call finds nothing to revoke.
        assert mgr.cancel(["q0", "blk0"]) == []

        # Unsettled, uncancelled work is undisturbed by all of the above.
        mgr.drain()
        assert rec.only("q2") == 4
        assert rec.only("q3") == 6
        assert rec.only("blk1") == "napped"

        # The poisoned blk0 completion has arrived by now (drain outlasts
        # its 1s nap) and was dropped: still exactly one callback.
        assert rec.count("blk0") == 1
        assert mgr.scheduler_stats()["cancelled"] == 3

        # Race 4: cancel-then-resubmit — a clean new lifecycle with the
        # bit-identical value an uncancelled run produces.
        mgr.forget(["q0", "q1", "blk0"])
        mgr.submit(
            WorkItem(
                key="q0",
                spec=("call", _double, (0,), {}),
                callback=rec.cb,
            )
        )
        mgr.drain()
        assert rec.events["q0"][-1] == 0 == _double(0)
        assert rec.count("q0") == 2  # one per lifecycle, never more

        # Exactly-once across the whole gauntlet.
        for key, events in rec.events.items():
            expected = 2 if key == "q0" else 1
            assert len(events) == expected, (key, events)
    finally:
        mgr.close()


@pytest.mark.parametrize("backend", ["thread", "process"])
def test_cancel_in_delegated_subqueue(tmp_path, backend):
    """Cancel reaches work already distributed to a hierarchical
    sub-pump's local queue (the steal surface): queued shards are purged
    from the sub-queues, not just the global queue, and the freed workers
    go on to complete unrelated work."""
    rec = _Recorder()
    mgr = _mk_manager(
        backend,
        tmp_path,
        n_workers=4,
        hierarchy=2,
        enable_backup_tasks=False,
    )
    try:
        for i in range(4):
            mgr.submit(
                WorkItem(
                    key=f"blk{i}",
                    spec=("call", _napper, (0.8,), {}),
                    callback=rec.cb,
                )
            )
        deadline = time.monotonic() + 30
        while sum(mgr.dispatch_counts.values()) < 4:
            assert time.monotonic() < deadline
            time.sleep(0.01)
        # Backlog lands in the sub-pumps' local queues behind the nappers.
        for i in range(12):
            mgr.submit(
                WorkItem(
                    key=f"s{i}",
                    spec=("call", _double, (i,), {}),
                    callback=rec.cb,
                    path=("in", i % 4),
                )
            )
        victims = [f"s{i}" for i in range(0, 12, 2)]
        cancelled = mgr.cancel(victims)
        assert sorted(cancelled) == sorted(victims)
        mgr.drain()
        for i in range(12):
            key = f"s{i}"
            assert rec.count(key) == 1, key
            if key in victims:
                assert isinstance(rec.only(key), TaskCancelled)
            else:
                assert rec.only(key) == 2 * i
    finally:
        mgr.close()


def test_cancel_unknown_and_settled_keys_noop():
    """Cancelling keys that were never submitted, or that already settled,
    revokes nothing and leaves the memoised results intact."""
    rec = _Recorder()
    mgr = Manager()
    mgr.start(1)
    try:
        mgr.submit(WorkItem(key="a", fn=lambda: 7, callback=rec.cb))
        mgr.drain()
        assert mgr.cancel(["a", "ghost"]) == []
        assert mgr.results()["a"] == 7
        assert rec.count("a") == 1
        assert mgr.scheduler_stats()["cancelled"] == 0
    finally:
        mgr.close()


def test_cancel_shared_key_fires_every_subscriber_once():
    """A shared (content-addressed) key with several subscribed callbacks
    settles TaskCancelled to ALL of them, each exactly once."""
    rec = _Recorder()
    mgr = Manager()
    mgr.start(1)
    try:
        mgr.submit(
            WorkItem(
                key="blk",
                fn=lambda: time.sleep(0.8) or "done",
                callback=rec.cb,
            )
        )
        deadline = time.monotonic() + 30
        while sum(mgr.dispatch_counts.values()) < 1:
            assert time.monotonic() < deadline
            time.sleep(0.01)
        journal = []
        lock = threading.Lock()
        for sub in range(3):
            mgr.submit(
                WorkItem(
                    key="shared",
                    fn=lambda: "never-runs",
                    shared=True,
                    callback=lambda k, v, s=sub: (
                        lock.__enter__(),
                        journal.append((s, v)),
                        lock.__exit__(None, None, None),
                    ),
                )
            )
        assert mgr.cancel(["shared"]) == ["shared"]
        mgr.drain()
        with lock:
            assert sorted(s for s, _ in journal) == [0, 1, 2]
            assert all(isinstance(v, TaskCancelled) for _, v in journal)
    finally:
        mgr.close()

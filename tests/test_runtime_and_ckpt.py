"""Runtime (Manager-Worker, fault tolerance, storage) + checkpoint tests."""

import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.configs import SHAPES, get_config, reduced_config
from repro.data import TokenPipeline
from repro.runtime import HierarchicalStore, Manager, WorkItem, simulate_cluster


class TestManager:
    def test_all_items_complete(self):
        mgr = Manager()
        for i in range(20):
            mgr.submit(WorkItem(key=f"k{i}", fn=lambda i=i: i * i))
        out = mgr.run(4, expected=20)
        assert out == {f"k{i}": i * i for i in range(20)}

    def test_retry_on_transient_failure(self):
        attempts = {}

        def flaky(key):
            attempts[key] = attempts.get(key, 0) + 1
            if attempts[key] < 3:
                raise RuntimeError("transient")
            return "ok"

        mgr = Manager(max_attempts=5)
        mgr.submit(WorkItem(key="a", fn=lambda: flaky("a")))
        out = mgr.run(2, expected=1)
        assert out["a"] == "ok"
        assert mgr.retries == 2

    def test_permanent_failure_surfaces(self):
        mgr = Manager(max_attempts=2)
        mgr.submit(WorkItem(key="bad", fn=lambda: 1 / 0))
        out = mgr.run(1, expected=1)
        assert isinstance(out["bad"], Exception)

    def test_straggler_backup_task(self):
        """A stuck item is cloned to an idle worker; first completion wins."""
        release = threading.Event()

        def slow():
            # first attempt blocks until released; the backup returns fast
            if not release.is_set():
                release.set()
                time.sleep(2.0)
                return "slow"
            return "fast"

        mgr = Manager(straggler_factor=0.5, max_attempts=3)
        for i in range(4):
            mgr.submit(WorkItem(key=f"quick{i}", fn=lambda: time.sleep(0.01) or "q"))
        mgr.submit(WorkItem(key="strag", fn=slow))
        out = mgr.run(3, expected=5)
        assert out["strag"] in ("fast", "slow")
        assert mgr.backups_launched >= 1

    def test_forget_releases_results_but_respects_races(self):
        """forget drops settled results + purges stale queued duplicates,
        but keeps a key whose losing attempt still holds a lease (the late
        completion must dedup, not resurrect)."""
        mgr = Manager()
        mgr._results["done"] = 1
        mgr._attempt_seq["done"] = 1
        mgr._queue.append(WorkItem(key="done", fn=lambda: 2))  # stale retry
        mgr._results["racing"] = 3
        mgr._attempt_seq["racing"] = 2
        mgr._running["racing#2"] = WorkItem(key="racing", fn=lambda: 3, attempts=2)
        mgr.forget(["done", "racing"])
        assert "done" not in mgr._results and not mgr._queue
        assert mgr._results["racing"] == 3  # lease outstanding: kept

    def test_cluster_sim_efficiency_degrades_gracefully(self):
        costs = [1.0] * 10000
        base = simulate_cluster(costs, n_nodes=1)
        big = simulate_cluster(costs, n_nodes=64)
        eff = base.makespan / (big.makespan * 64)
        assert 0.8 < eff <= 1.01


class TestStorage:
    def test_put_get_roundtrip(self):
        st = HierarchicalStore(ram_bytes=1 << 20)
        a = np.arange(100, dtype=np.float32)
        st.put("x", a)
        np.testing.assert_array_equal(st.get("x"), a)

    def test_spill_to_disk_and_reload(self):
        st = HierarchicalStore(ram_bytes=1000)  # tiny RAM tier
        arrays = {f"k{i}": np.full((200,), i, np.float32) for i in range(5)}
        for k, v in arrays.items():
            st.put(k, v)
        assert st.spills > 0
        for k, v in arrays.items():
            got = st.get(k)
            assert got is not None
            np.testing.assert_array_equal(np.asarray(got), v)

    def test_content_addressed_keys_survive_reopen(self, tmp_path):
        """Disk filenames are content-addressed (sha256 of the key), so a
        store re-opened on the same directory — by another process, with a
        different hash seed — resolves the same keys. This is the property
        the adaptive-study resume path relies on."""
        a = np.arange(40, dtype=np.float32)
        st = HierarchicalStore(ram_bytes=1 << 20, disk_dir=str(tmp_path))
        st.put("((0, 'seg', ()), (('p0', 1.5),))", a)
        st.persist("((0, 'seg', ()), (('p0', 1.5),))")
        st2 = HierarchicalStore(ram_bytes=1 << 20, disk_dir=str(tmp_path))
        got = st2.get("((0, 'seg', ()), (('p0', 1.5),))")
        np.testing.assert_array_equal(np.asarray(got), a)
        assert st2.disk_hits == 1 and st2.hits == 0
        assert st2.get("missing") is None and st2.misses == 1

    def test_disk_hit_promoted_to_ram_tier(self, tmp_path):
        a = np.arange(16, dtype=np.float32)
        st = HierarchicalStore(ram_bytes=1 << 20, disk_dir=str(tmp_path))
        st.put("k", a)
        st.persist("k")
        st2 = HierarchicalStore(ram_bytes=1 << 20, disk_dir=str(tmp_path))
        np.testing.assert_array_equal(np.asarray(st2.get("k")), a)
        assert st2.disk_hits == 1
        np.testing.assert_array_equal(np.asarray(st2.get("k")), a)
        assert st2.disk_hits == 1 and st2.hits == 1  # second read: RAM

    def test_dict_payload_roundtrip_through_disk(self, tmp_path):
        st = HierarchicalStore(ram_bytes=1 << 20, disk_dir=str(tmp_path))
        state = {"mask": np.ones((4, 4), bool), "gray": np.eye(4, dtype=np.float32)}
        st.put("s", state)
        st.persist("s")
        st2 = HierarchicalStore(ram_bytes=1 << 20, disk_dir=str(tmp_path))
        got = st2.get("s")
        assert set(got) == {"mask", "gray"}
        np.testing.assert_array_equal(got["mask"], state["mask"])
        np.testing.assert_array_equal(got["gray"], state["gray"])


class TestCheckpointer:
    def test_roundtrip_and_resume(self, tmp_path):
        ck = Checkpointer(str(tmp_path), keep=2)
        tree = {"w": jnp.arange(6.0).reshape(2, 3), "b": jnp.zeros(3)}
        ck.save(5, tree, metadata={"pipeline": {"step": 5, "seed": 0, "host_id": 0}})
        restored, meta = ck.restore(tree)
        np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))
        assert meta["pipeline"]["step"] == 5
        assert ck.latest_step() == 5

    def test_async_save_and_gc(self, tmp_path):
        ck = Checkpointer(str(tmp_path), keep=2)
        tree = {"w": jnp.ones((4,))}
        for s in (1, 2, 3):
            ck.save_async(s, tree)
        ck.wait()
        assert ck.latest_step() == 3
        steps = sorted(p.name for p in tmp_path.glob("step_*"))
        assert len(steps) == 2  # keep=2 garbage collection

    def test_atomic_no_partial_dirs(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        ck.save(1, {"w": jnp.ones((2,))})
        assert not list(tmp_path.glob("*.tmp"))


class TestDataPipeline:
    def test_deterministic_and_disjoint_hosts(self):
        cfg = reduced_config(get_config("yi_6b"))
        import dataclasses

        shape = dataclasses.replace(SHAPES["train_4k"], seq_len=16, global_batch=4)
        p0 = TokenPipeline(cfg, shape, host_id=0, n_hosts=2, seed=1)
        p1 = TokenPipeline(cfg, shape, host_id=1, n_hosts=2, seed=1)
        b0a, b0b = p0.batch_at(3), p0.batch_at(3)
        np.testing.assert_array_equal(b0a["tokens"], b0b["tokens"])  # deterministic
        assert not np.array_equal(b0a["tokens"], p1.batch_at(3)["tokens"])  # disjoint

    def test_state_resume(self):
        cfg = reduced_config(get_config("yi_6b"))
        import dataclasses

        shape = dataclasses.replace(SHAPES["train_4k"], seq_len=16, global_batch=4)
        p = TokenPipeline(cfg, shape, seed=7)
        it = iter(p)
        next(it), next(it)
        st = p.state()
        want = p.batch_at(p.step)
        p2 = TokenPipeline(cfg, shape, seed=0)
        p2.restore(st)
        np.testing.assert_array_equal(p2.batch_at(p2.step)["tokens"], want["tokens"])

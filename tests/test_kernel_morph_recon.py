"""Pallas morph-recon kernel vs the jnp oracle: shape/connectivity sweeps and
hypothesis property tests, run in interpret mode on CPU."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need hypothesis; skip cleanly without it
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.kernels.morph_recon import morph_reconstruct_pallas, tile_sweep
from repro.kernels.ref import morph_reconstruct_ref


def random_case(h, w, seed):
    rng = np.random.default_rng(seed)
    mask = rng.uniform(0, 100, (h, w)).astype(np.float32)
    marker = np.maximum(mask - rng.uniform(5, 40, (h, w)).astype(np.float32), 0)
    # sprinkle strong peaks so reconstruction has something to propagate
    for _ in range(max(1, h * w // 256)):
        y, x = rng.integers(0, h), rng.integers(0, w)
        marker[y, x] = mask[y, x]
    return jnp.asarray(marker), jnp.asarray(mask)


@pytest.mark.parametrize("h,w", [(16, 16), (24, 40), (32, 32), (64, 48), (65, 33)])
@pytest.mark.parametrize("conn", [4, 8])
def test_kernel_matches_ref_shapes(h, w, conn):
    marker, mask = random_case(h, w, seed=h * 1000 + w + conn)
    ref = morph_reconstruct_ref(marker, mask, conn=conn)
    got = morph_reconstruct_pallas(
        marker, mask, conn=conn, block=(16, 16), inner_iters=4, interpret=True
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=0, rtol=0)


@pytest.mark.parametrize("block", [(8, 8), (16, 32), (64, 64)])
def test_kernel_block_shape_invariance(block):
    marker, mask = random_case(48, 48, seed=7)
    ref = morph_reconstruct_ref(marker, mask, conn=8)
    got = morph_reconstruct_pallas(
        marker, mask, conn=8, block=block, inner_iters=6, interpret=True
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=0, rtol=0)


def test_tile_sweep_is_contractive_and_bounded():
    """Each sweep keeps marker ≤ result ≤ mask (monotone convergence)."""
    marker, mask = random_case(32, 32, seed=11)
    out = tile_sweep(marker, mask, conn=8, block=(16, 16), inner_iters=3, interpret=True)
    assert bool(jnp.all(out >= marker - 1e-6))
    assert bool(jnp.all(out <= mask + 1e-6))


def test_binary_reconstruction_connectivity():
    """4- vs 8-conn differ on a diagonal bridge — the FH/RC/WConn parameters
    of the paper change results exactly through this mechanism."""
    mask = np.zeros((9, 9), np.float32)
    mask[1:4, 1:4] = 1.0
    mask[4, 4] = 1.0  # diagonal link
    mask[5:8, 5:8] = 1.0
    marker = np.zeros_like(mask)
    marker[2, 2] = 1.0
    r4 = morph_reconstruct_pallas(jnp.asarray(marker), jnp.asarray(mask), conn=4, block=(8, 8), interpret=True)
    r8 = morph_reconstruct_pallas(jnp.asarray(marker), jnp.asarray(mask), conn=8, block=(8, 8), interpret=True)
    assert float(r4[6, 6]) == 0.0  # cannot cross the diagonal with 4-conn
    assert float(r8[6, 6]) == 1.0


@settings(max_examples=15, deadline=None)
@given(
    h=st.integers(min_value=8, max_value=40),
    w=st.integers(min_value=8, max_value=40),
    conn=st.sampled_from([4, 8]),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_property_kernel_equals_oracle(h, w, conn, seed):
    marker, mask = random_case(h, w, seed=seed)
    ref = morph_reconstruct_ref(marker, mask, conn=conn)
    got = morph_reconstruct_pallas(
        marker, mask, conn=conn, block=(16, 16), inner_iters=5, interpret=True
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=0, rtol=0)
